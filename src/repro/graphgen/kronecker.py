"""Graph500-style Kronecker (R-MAT) graph generator (paper §8.1, Fig 9).

The paper's weak-scaling study uses the Kronecker generator of Leskovec et al.
[arXiv:0812.4905] with Graph500 parameters. Graph500's reference generator is
the recursive-matrix (R-MAT) sampler with (A,B,C,D) = (0.57, 0.19, 0.19, 0.05)
and edge factor 16. We reproduce exactly that, vectorized in numpy.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph

GRAPH500_A = 0.57
GRAPH500_B = 0.19
GRAPH500_C = 0.19
GRAPH500_D = 0.05
GRAPH500_EDGE_FACTOR = 16


def rmat_edges(scale: int, n_edges: int, *, a: float = GRAPH500_A,
               b: float = GRAPH500_B, c: float = GRAPH500_C,
               seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Sample ``n_edges`` edges of a 2^scale-vertex R-MAT graph."""
    rng = np.random.default_rng(seed)
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    ab = a + b
    c_norm = c / (1.0 - ab)
    a_norm = a / ab
    for bit in range(scale):
        ii = rng.random(n_edges) > ab
        jj = rng.random(n_edges) > np.where(ii, c_norm, a_norm)
        src += ii.astype(np.int64) << bit
        dst += jj.astype(np.int64) << bit
    # Graph500 permutes vertex labels so degree is not correlated with id.
    perm = rng.permutation(1 << scale).astype(np.int64)
    return perm[src], perm[dst]


def kronecker_graph(scale: int, *, edge_factor: int = GRAPH500_EDGE_FACTOR,
                    seed: int = 0, undirected: bool = True,
                    weighted: bool = False) -> Graph:
    n = 1 << scale
    m = edge_factor * n
    src, dst = rmat_edges(scale, m, seed=seed)
    w = None
    if weighted:
        rng = np.random.default_rng(seed + 1)
        w = rng.uniform(1.0, 10.0, size=src.shape).astype(np.float32)
    g = Graph(n, src, dst, w).drop_self_loops().dedup()
    if undirected:
        g = g.as_undirected()
    return g
