from repro.graphgen.kronecker import kronecker_graph, rmat_edges
from repro.graphgen.synthetic import powerlaw_graph, ring_graph, grid_graph, random_graph

__all__ = [
    "kronecker_graph", "rmat_edges", "powerlaw_graph", "ring_graph",
    "grid_graph", "random_graph",
]
