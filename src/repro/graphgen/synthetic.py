"""Synthetic graph generators used by tests and benchmarks.

- ``powerlaw_graph``: Zipf out-degree sampler, P(degree=d) ~ d^-alpha
  (paper §3 Eq. 1; alpha in [2,3] for real-world graphs).
- ``ring_graph`` / ``grid_graph``: large-diameter graphs standing in for the
  USARoad road network regime (paper §8, SSSP on large-diameter graphs).
- ``random_graph``: Erdos-Renyi-ish for property tests.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph


def powerlaw_graph(n_vertices: int, alpha: float = 2.2, *, avg_degree: int = 8,
                   seed: int = 0, weighted: bool = False,
                   undirected: bool = False) -> Graph:
    rng = np.random.default_rng(seed)
    # Zipf-distributed out-degrees, clipped and rescaled to the target mean.
    deg = rng.zipf(alpha, size=n_vertices).astype(np.int64)
    deg = np.minimum(deg, n_vertices - 1)
    scale = avg_degree / max(deg.mean(), 1e-9)
    deg = np.maximum((deg * scale).astype(np.int64), 0)
    src = np.repeat(np.arange(n_vertices, dtype=np.int64), deg)
    # Preferential-style destinations: mix of uniform and hub-biased picks so
    # max in-degree is also skewed (hubs), like the WebBase/LiveJournal stats.
    n_e = src.shape[0]
    hubs = rng.integers(0, max(n_vertices // 100, 1), size=n_e)
    unif = rng.integers(0, n_vertices, size=n_e)
    take_hub = rng.random(n_e) < 0.15
    dst = np.where(take_hub, hubs, unif).astype(np.int64)
    w = None
    if weighted:
        w = rng.uniform(1.0, 10.0, size=n_e).astype(np.float32)
    g = Graph(n_vertices, src, dst, w).drop_self_loops().dedup()
    if undirected:
        g = g.as_undirected()
    return g


def ring_graph(n_vertices: int, *, weighted: bool = False, seed: int = 0) -> Graph:
    """Cycle graph — diameter n/2; the adversarial case for vertex-centric."""
    v = np.arange(n_vertices, dtype=np.int64)
    src = v
    dst = (v + 1) % n_vertices
    w = None
    if weighted:
        rng = np.random.default_rng(seed)
        w = rng.uniform(1.0, 10.0, size=src.shape).astype(np.float32)
    return Graph(n_vertices, src, dst, w).as_undirected()


def grid_graph(side: int, *, weighted: bool = False, seed: int = 0) -> Graph:
    """side x side 4-neighbour grid — the road-network (USARoad) stand-in."""
    n = side * side
    idx = np.arange(n, dtype=np.int64)
    r, c = idx // side, idx % side
    edges = []
    right = (r * side + c + 1)[c < side - 1]
    edges.append(np.stack([idx[c < side - 1], right], 1))
    down = ((r + 1) * side + c)[r < side - 1]
    edges.append(np.stack([idx[r < side - 1], down], 1))
    e = np.concatenate(edges, 0)
    w = None
    if weighted:
        rng = np.random.default_rng(seed)
        w = rng.uniform(1.0, 10.0, size=e.shape[0]).astype(np.float32)
    return Graph(n, e[:, 0], e[:, 1], w).as_undirected()


def random_graph(n_vertices: int, n_edges: int, *, seed: int = 0,
                 weighted: bool = False, undirected: bool = False) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, size=n_edges).astype(np.int64)
    dst = rng.integers(0, n_vertices, size=n_edges).astype(np.int64)
    w = None
    if weighted:
        w = rng.uniform(1.0, 10.0, size=n_edges).astype(np.float32)
    g = Graph(n_vertices, src, dst, w).drop_self_loops().dedup()
    if undirected:
        g = g.as_undirected()
    return g
