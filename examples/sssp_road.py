"""SSSP on a large-diameter road-network proxy (paper §8 USARoad study):
subgraph-centric local fixed points vs one-hop vertex-centric supersteps,
with a locality-preserving partition.

    PYTHONPATH=src python examples/sssp_road.py
"""
import numpy as np

from repro.algos import SSSP
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.graphgen import grid_graph


def main():
    g = grid_graph(120, weighted=True, seed=9)   # 14.4k vertices, diam ~240
    for name, part, mode in (("DRONE-VC sc", "range", "sc"),
                             ("DRONE-VC vc-mode", "range", "vc")):
        pg = partition_and_build(g, 16, part)
        res, st = run_sim(SSSP(), pg, {"source": 0},
                          EngineConfig(mode=mode, max_supersteps=50_000))
        dist = pg.collect(res, fill=np.float32(np.inf))
        print(f"{name:18s} supersteps={st.supersteps:5d} "
              f"messages={st.total_messages:9d} "
              f"max_dist={np.nanmax(np.where(np.isfinite(dist), dist, np.nan)):.1f}")


if __name__ == "__main__":
    main()
