"""SSSP on a large-diameter road-network proxy (paper §8 USARoad study):
subgraph-centric local fixed points vs one-hop vertex-centric supersteps,
with a locality-preserving partition.

    PYTHONPATH=src python examples/sssp_road.py
"""
import numpy as np

from repro.algos import SSSP
from repro.core import EngineConfig
from repro.graphgen import grid_graph
from repro.session import GraphSession


def main():
    g = grid_graph(120, weighted=True, seed=9)   # 14.4k vertices, diam ~240
    sess = GraphSession.from_graph(g, 16, "range")
    for name, mode in (("DRONE-VC sc", "sc"), ("DRONE-VC vc-mode", "vc")):
        res, st = sess.query(SSSP(), {"source": 0}, warm=False,
                             cfg=EngineConfig(mode=mode,
                                              max_supersteps=50_000))
        dist = sess.pg.collect(res, fill=np.float32(np.inf))
        print(f"{name:18s} supersteps={st.supersteps:5d} "
              f"messages={st.total_messages:9d} "
              f"max_dist={np.nanmax(np.where(np.isfinite(dist), dist, np.nan)):.1f}")


if __name__ == "__main__":
    main()
