"""Quickstart: DRONE/SVHM connected components on a Graph500 Kronecker graph.

    PYTHONPATH=src python examples/quickstart.py

Builds a power-law graph, partitions it with the paper's CDBH vertex-cut,
runs subgraph-centric CC, and prints the paper's execution metrics
(supersteps / (key,value) messages) next to the vertex-centric baseline.
"""
import numpy as np

from repro.algos import ConnectedComponents
from repro.core import (EngineConfig, partition_and_build, partition_metrics,
                        run_sim)
from repro.graphgen import kronecker_graph


def main():
    g = kronecker_graph(14, seed=7)           # 2^14 vertices, power-law
    print(f"graph: {g.n_vertices} vertices, {g.n_edges} edges")

    pg = partition_and_build(g, n_parts=16, partitioner="cdbh")
    print("partitioning:", partition_metrics(pg))

    labels, sc = run_sim(ConnectedComponents(), pg, None,
                         EngineConfig(mode="sc"))
    _, vc = run_sim(ConnectedComponents(), pg, None, EngineConfig(mode="vc"))
    out = pg.collect(labels, fill=-1)
    n_components = len(np.unique(out))
    print(f"components: {n_components}")
    print(f"subgraph-centric: {sc.supersteps} supersteps, "
          f"{sc.total_messages} messages")
    print(f"vertex-centric  : {vc.supersteps} supersteps, "
          f"{vc.total_messages} messages")
    assert sc.supersteps <= vc.supersteps


if __name__ == "__main__":
    main()
