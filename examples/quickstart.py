"""Quickstart: DRONE/SVHM connected components on a Graph500 Kronecker graph.

    PYTHONPATH=src python examples/quickstart.py

Opens a ``GraphSession`` — the serving API — over a power-law graph
partitioned with the paper's CDBH vertex-cut, runs subgraph-centric CC, and
prints the paper's execution metrics (supersteps / (key,value) messages)
next to the vertex-centric baseline. The session keeps the graph resident on
device and caches each compiled runner, so the repeated query at the end
costs compile_time=0.
"""
import numpy as np

from repro.algos import ConnectedComponents
from repro.core import EngineConfig, partition_metrics
from repro.graphgen import kronecker_graph
from repro.session import GraphSession


def main():
    g = kronecker_graph(14, seed=7)           # 2^14 vertices, power-law
    print(f"graph: {g.n_vertices} vertices, {g.n_edges} edges")

    sess = GraphSession.from_graph(g, n_parts=16, partitioner="cdbh")
    print("partitioning:", partition_metrics(sess.pg))

    labels, sc = sess.query(ConnectedComponents())
    # warm=False: the vertex-centric baseline must run cold — warm="auto"
    # would (soundly) restart it from the already-converged SC labels
    _, vc = sess.query(ConnectedComponents(), warm=False,
                       cfg=EngineConfig(mode="vc"))
    out = sess.pg.collect(labels, fill=-1)
    n_components = len(np.unique(out))
    print(f"components: {n_components}")
    print(f"subgraph-centric: {sc.supersteps} supersteps, "
          f"{sc.total_messages} messages "
          f"(compiled in {sc.compile_time:.2f}s)")
    print(f"vertex-centric  : {vc.supersteps} supersteps, "
          f"{vc.total_messages} messages")
    assert sc.supersteps <= vc.supersteps

    # a repeated query reuses the cached executable: zero retrace
    _, again = sess.query(ConnectedComponents(), warm=False)
    print(f"repeat query    : compile_time={again.compile_time:.0f}s "
          f"(cache hit), wall={again.wall_time*1e3:.0f} ms")
    assert again.compile_time == 0.0


if __name__ == "__main__":
    main()
