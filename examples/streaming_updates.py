"""Continuously-updating workload: out-of-core ingest, live edge inserts,
warm-start incremental SSSP (docs/STREAMING.md).

A producer appends edges to a chunked on-disk edge log; the two-pass
streaming pipeline builds the PartitionedGraph with peak edge memory bounded
by the chunk size; then batches of new edges are routed through the same
frozen pure hashes and patched into the affected partitions, and SSSP
restarts from the previous converged distances instead of from scratch.

    PYTHONPATH=src python examples/streaming_updates.py
"""
import tempfile

import numpy as np

from repro.algos import SSSP
from repro.core import EngineConfig, run_sim
from repro.graphgen import powerlaw_graph
from repro.stream import (EdgeDelta, apply_delta, streaming_ingest,
                          write_edge_log)


def main():
    g = powerlaw_graph(20_000, avg_degree=8, seed=0,
                       weighted=True).as_undirected()
    log_dir = tempfile.mkdtemp(prefix="drone_edgelog_")
    meta = write_edge_log(g, log_dir, chunk_size=32_768)
    print(f"edge log: {meta.n_edges} edges in {meta.n_chunks} chunks "
          f"of {meta.chunk_size}")

    pg, ctx, st = streaming_ingest(log_dir, 8, "cdbh")
    print(f"ingest: {st.ingest_edges_per_s/1e6:.2f} Medges/s, "
          f"peak stream mem {st.peak_stream_bytes/2**20:.1f} MiB "
          f"(bound {st.stream_bound_bytes/2**20:.1f} MiB, "
          f"full edge list would be "
          f"{meta.n_edges * 20/2**20:.1f} MiB)")

    res, stats = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    prev = pg.collect(res, fill=np.float32(np.inf))
    print(f"initial SSSP: {stats.supersteps} supersteps")

    rng = np.random.default_rng(1)
    for batch in range(3):
        n = g.n_edges // 200
        s = rng.integers(0, pg.n_vertices, n)
        d = rng.integers(0, pg.n_vertices, n)
        keep = s != d
        s, d = s[keep], d[keep]
        w = rng.uniform(5, 10, s.size).astype(np.float32)
        dst = apply_delta(pg, ctx, EdgeDelta(
            add_src=np.concatenate([s, d]), add_dst=np.concatenate([d, s]),
            add_w=np.concatenate([w, w])))
        cold, st_c = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
        warm, st_w = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                             init_state=prev)
        ok = np.allclose(
            np.nan_to_num(pg.collect(warm, fill=np.float32(np.inf)), posinf=-1),
            np.nan_to_num(pg.collect(cold, fill=np.float32(np.inf)), posinf=-1))
        print(f"batch {batch}: +{dst.n_added} edges "
              f"({dst.parts_patched} partitions patched, "
              f"slots {dst.n_slots_before}->{dst.n_slots_after}) | "
              f"cold {st_c.supersteps} supersteps, warm {st_w.supersteps} "
              f"| allclose={ok}")
        prev = pg.collect(warm, fill=np.float32(np.inf))


if __name__ == "__main__":
    main()
