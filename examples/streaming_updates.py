"""Continuously-updating workload on one ``GraphSession``: out-of-core
ingest, buffered live edge traffic, auto-warm-start incremental SSSP,
membership compaction (docs/STREAMING.md, docs/API.md).

A producer appends edges to a chunked on-disk edge log; the session opens
over it with the two-pass streaming pipeline (peak edge memory bounded by
the chunk size). Producer traffic then flows through ``session.update`` —
coalesced by the internal DeltaBuffer, applied as one patch per flush — and
every ``session.query`` after an insert-only flush automatically restarts
SSSP from the previous converged distances instead of from scratch, on the
same compiled runner (zero retraces while the padded shapes hold). After a
delete-heavy phase ``session.compact()`` shrinks the padded device buffers
back down.

    PYTHONPATH=src python examples/streaming_updates.py
"""
import tempfile

import numpy as np

from repro.algos import SSSP
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession
from repro.stream import write_edge_log


def main():
    g = powerlaw_graph(20_000, avg_degree=8, seed=0,
                       weighted=True).as_undirected()
    log_dir = tempfile.mkdtemp(prefix="drone_edgelog_")
    meta = write_edge_log(g, log_dir, chunk_size=32_768)
    print(f"edge log: {meta.n_edges} edges in {meta.n_chunks} chunks "
          f"of {meta.chunk_size}")

    sess = GraphSession.from_edge_log(log_dir, 8, "cdbh",
                                      max_buffer_edges=512)
    st = sess.ingest_stats
    print(f"ingest: {st.ingest_edges_per_s/1e6:.2f} Medges/s, "
          f"peak stream mem {st.peak_stream_bytes/2**20:.1f} MiB "
          f"(bound {st.stream_bound_bytes/2**20:.1f} MiB, "
          f"full edge list would be "
          f"{meta.n_edges * 20/2**20:.1f} MiB)")

    _, stats = sess.query(SSSP(), {"source": 0})
    print(f"initial SSSP: {stats.supersteps} supersteps "
          f"(compiled in {stats.compile_time:.2f}s)")

    # ---- continuous producer traffic through the buffered session -------- #
    rng = np.random.default_rng(1)
    for batch in range(3):
        n = g.n_edges // 200
        s = rng.integers(0, sess.pg.n_vertices, n)
        d = rng.integers(0, sess.pg.n_vertices, n)
        keep = s != d
        s, d = s[keep], d[keep]
        w = rng.uniform(5, 10, s.size).astype(np.float32)
        e_before, s_before = sess.pg.n_edges, sess.pg.n_slots
        f_before = sess.stats.flushes
        # the producer emits tiny add ops; the buffer coalesces and flushes
        for i in range(0, s.size, 64):
            sess.update(adds=(np.concatenate([s[i:i+64], d[i:i+64]]),
                              np.concatenate([d[i:i+64], s[i:i+64]]),
                              np.concatenate([w[i:i+64], w[i:i+64]])))
        sess.flush()
        warm, st_w = sess.query(SSSP(), {"source": 0})     # warm="auto"
        cold, st_c = sess.query(SSSP(), {"source": 0}, warm=False)
        ok = (np.asarray(warm) == np.asarray(cold)).all()
        assert ok, "warm-auto SSSP diverged from cold"
        assert st_w.supersteps < st_c.supersteps, (st_w.supersteps,
                                                   st_c.supersteps)
        print(f"batch {batch}: +{sess.pg.n_edges - e_before} edges in "
              f"{sess.stats.flushes - f_before} flushes, "
              f"slots {s_before}->{sess.pg.n_slots} | "
              f"cold {st_c.supersteps} supersteps, warm {st_w.supersteps} "
              f"| bit-identical={ok} "
              f"| retraced={'yes' if st_w.compile_time else 'no'}")

    # ---- delete-heavy phase, then compact the zombie members ------------- #
    sel = rng.choice(g.n_edges, size=g.n_edges // 3, replace=False)
    sess.update(deletes=(np.concatenate([g.src[sel], g.dst[sel]]),
                         np.concatenate([g.dst[sel], g.src[sel]])))
    sess.flush()
    v0, e0, s0 = sess.pg.v_max, sess.pg.e_max, sess.pg.n_slots
    cs = sess.compact()
    print(f"compact: evicted {cs.n_evicted} zombie members, "
          f"v_max {v0}->{sess.pg.v_max}, e_max {e0}->{sess.pg.e_max}, "
          f"n_slots {s0}->{sess.pg.n_slots}")
    _, stats = sess.query(SSSP(), {"source": 0})
    print(f"post-compact SSSP: {stats.supersteps} supersteps "
          f"(graph unchanged by compaction, buffers smaller)")
    print(f"session: {sess.stats.queries} queries, "
          f"{sess.stats.cache_misses} compiles, "
          f"{sess.stats.warm_queries} warm, {sess.stats.uploads} uploads")


if __name__ == "__main__":
    main()
