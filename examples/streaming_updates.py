"""Continuously-updating workload: out-of-core ingest, buffered live edge
traffic, warm-start incremental SSSP, membership compaction
(docs/STREAMING.md).

A producer appends edges to a chunked on-disk edge log; the two-pass
streaming pipeline builds the PartitionedGraph with peak edge memory bounded
by the chunk size. Producer traffic then flows through a coalescing
``DeltaBuffer`` (one partition rebuild per flush instead of per op), SSSP
restarts from the previous converged distances instead of from scratch, and
after a delete-heavy phase ``compact`` shrinks the padded device buffers
back down.

    PYTHONPATH=src python examples/streaming_updates.py
"""
import tempfile

import numpy as np

from repro.algos import SSSP
from repro.core import EngineConfig, run_sim
from repro.graphgen import powerlaw_graph
from repro.stream import (DeltaBuffer, compact, streaming_ingest,
                          write_edge_log)


def main():
    g = powerlaw_graph(20_000, avg_degree=8, seed=0,
                       weighted=True).as_undirected()
    log_dir = tempfile.mkdtemp(prefix="drone_edgelog_")
    meta = write_edge_log(g, log_dir, chunk_size=32_768)
    print(f"edge log: {meta.n_edges} edges in {meta.n_chunks} chunks "
          f"of {meta.chunk_size}")

    pg, ctx, st = streaming_ingest(log_dir, 8, "cdbh")
    print(f"ingest: {st.ingest_edges_per_s/1e6:.2f} Medges/s, "
          f"peak stream mem {st.peak_stream_bytes/2**20:.1f} MiB "
          f"(bound {st.stream_bound_bytes/2**20:.1f} MiB, "
          f"full edge list would be "
          f"{meta.n_edges * 20/2**20:.1f} MiB)")

    res, stats = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    prev = pg.collect(res, fill=np.float32(np.inf))
    print(f"initial SSSP: {stats.supersteps} supersteps")

    # ---- continuous producer traffic through the coalescing buffer ------- #
    buf = DeltaBuffer(pg, ctx, max_edges=512)
    rng = np.random.default_rng(1)
    for batch in range(3):
        n = g.n_edges // 200
        s = rng.integers(0, pg.n_vertices, n)
        d = rng.integers(0, pg.n_vertices, n)
        keep = s != d
        s, d = s[keep], d[keep]
        w = rng.uniform(5, 10, s.size).astype(np.float32)
        # the producer emits tiny add ops; the buffer coalesces and flushes
        e_before, s_before, f_before = pg.n_edges, pg.n_slots, \
            buf.stats.n_flushes
        for i in range(0, s.size, 64):
            buf.add(np.concatenate([s[i:i+64], d[i:i+64]]),
                    np.concatenate([d[i:i+64], s[i:i+64]]),
                    np.concatenate([w[i:i+64], w[i:i+64]]))
        buf.flush()
        cold, st_c = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
        warm, st_w = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                             init_state=prev)
        ok = np.allclose(
            np.nan_to_num(pg.collect(warm, fill=np.float32(np.inf)), posinf=-1),
            np.nan_to_num(pg.collect(cold, fill=np.float32(np.inf)), posinf=-1))
        print(f"batch {batch}: +{pg.n_edges - e_before} edges in "
              f"{buf.stats.n_flushes - f_before} flushes, "
              f"slots {s_before}->{pg.n_slots} | "
              f"cold {st_c.supersteps} supersteps, warm {st_w.supersteps} "
              f"| allclose={ok}")
        prev = pg.collect(warm, fill=np.float32(np.inf))

    # ---- delete-heavy phase, then compact the zombie members ------------- #
    sel = rng.choice(g.n_edges, size=g.n_edges // 3, replace=False)
    buf.delete(np.concatenate([g.src[sel], g.dst[sel]]),
               np.concatenate([g.dst[sel], g.src[sel]]))
    buf.flush()
    v0, e0, s0 = pg.v_max, pg.e_max, pg.n_slots
    cs = compact(pg, ctx)
    print(f"compact: evicted {cs.n_evicted} zombie members, "
          f"v_max {v0}->{pg.v_max}, e_max {e0}->{pg.e_max}, "
          f"n_slots {s0}->{pg.n_slots}")
    res, stats = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    print(f"post-compact SSSP: {stats.supersteps} supersteps "
          f"(graph unchanged by compaction, buffers smaller)")


if __name__ == "__main__":
    main()
