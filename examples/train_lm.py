"""End-to-end LM training driver (deliverable b): train a ~100M-class model
for a few hundred steps on the synthetic pipeline, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py             # ~small olmo-family
    PYTHONPATH=src python examples/train_lm.py --arch jamba_v01_52b --steps 50

This wraps repro.launch.train; kill it mid-run and re-invoke with --resume to
exercise fault tolerance.
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    _, hist = train(args.arch, smoke=True, steps=args.steps, batch=8,
                    seq=128, ckpt_dir=f"ckpts/{args.arch}", ckpt_every=50,
                    resume=args.resume, peak_lr=1e-3)
    print(f"loss {hist[0]:.3f} -> {hist[-1]:.3f}")
    assert hist[-1] < hist[0]


if __name__ == "__main__":
    main()
