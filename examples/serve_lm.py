"""Batched LM serving: prefill a batch of prompts, then greedy-decode with
the per-arch KV/state cache (deliverable b, serving flavour).

    PYTHONPATH=src python examples/serve_lm.py --arch jamba_v01_52b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.training import steps as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_model(key, cfg)
    max_len = args.prompt_len + args.gen + (cfg.frontend_len or 0)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.frontend:
        batch["frontend"] = jnp.zeros((args.batch, cfg.frontend_len,
                                       cfg.frontend_dim))
    memory = M._encode(params, batch, cfg) if cfg.n_enc_layers else None

    prefill = jax.jit(S.make_prefill_step(cfg, max_len))
    step = jax.jit(S.make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    nxt, caches = prefill(params, batch)
    out = [nxt]
    for _ in range(args.gen - 1):
        db = {"tokens": nxt[:, None]}
        if memory is not None:
            db["memory"] = memory
        nxt, caches = step(params, caches, db)
        out.append(nxt)
    toks = jnp.stack(out, axis=1)
    dt = time.time() - t0
    print(f"{args.arch}: generated {args.batch}x{args.gen} tokens in {dt:.2f}s"
          f" ({args.batch*args.gen/dt:.1f} tok/s on CPU)")
    print("sample:", toks[0, :16].tolist())
    assert bool(jnp.isfinite(toks.astype(jnp.float32)).all())


if __name__ == "__main__":
    main()
