"""PageRank (paper §7.2, asynchronous accumulative) on a power-law graph,
comparing the paper's DRONE-VC against the DRONE-EC baseline — one
``GraphSession`` per partitioning (sessions are bound to one partitioned
graph; the two cuts are two different graphs on device).

    PYTHONPATH=src python examples/pagerank_powerlaw.py
"""
import numpy as np

from repro.algos import PageRank
from repro.core import EngineConfig
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession


def main():
    g = powerlaw_graph(30_000, alpha=2.2, avg_degree=12, seed=11)
    cfg = EngineConfig(mode="sc", max_local_iters=200)
    pr = PageRank(tol=1e-8)
    rows = []
    for name, part in (("DRONE-VC (cdbh)", "cdbh"), ("DRONE-EC (rh)", "rh-ec")):
        sess = GraphSession.from_graph(g, 16, part, cfg=cfg)
        res, st = sess.query(pr, {"n_vertices": g.n_vertices})
        ranks = sess.pg.collect(res, fill=0.0)
        top = np.argsort(-ranks)[:5]
        rows.append((name, st.supersteps, st.total_messages, st.wall_time,
                     ranks))
        print(f"{name:18s} supersteps={st.supersteps:4d} "
              f"messages={st.total_messages:9d} time={st.wall_time:.2f}s "
              f"top5={top.tolist()}")
    # both cuts converge to the same ranks (within the async tolerance)
    assert np.allclose(rows[0][4], rows[1][4], atol=5e-5), \
        float(np.abs(rows[0][4] - rows[1][4]).max())
    print("rank agreement OK; mass =", float(rows[0][4].sum()))


if __name__ == "__main__":
    main()
