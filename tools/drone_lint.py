"""drone-lint CLI: run the repro.analysis rules over source trees.

    python tools/drone_lint.py src/repro                 # gate on baseline
    python tools/drone_lint.py --update-baseline src/repro
    python tools/drone_lint.py --no-baseline --select DL005 src/repro/kernels
    python tools/drone_lint.py --list-rules

Exit status is 0 when no *new* findings exist (everything is either fixed,
suppressed inline, or absorbed by the checked-in baseline at
``tools/drone_lint_baseline.json``), 1 otherwise. ``--no-baseline`` is the
strict mode CI uses on ``src/repro/kernels``: every finding fails.
"""
from __future__ import annotations

import argparse
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.analysis import (                      # noqa: E402
    RULES, analyze_paths, baseline_delta, load_baseline, write_baseline)

DEFAULT_BASELINE = os.path.join(ROOT, "tools", "drone_lint_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="drone_lint",
        description="AST trace-safety / cache-key / kernel-contract linter")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories (default: src/repro)")
    ap.add_argument("--select", action="append", default=None,
                    metavar="DLnnn", help="run only these rule codes "
                    "(repeatable or comma-separated)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON path (default: "
                    "tools/drone_lint_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="strict mode: ignore the baseline, fail on every "
                    "finding")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to absorb current findings")
    ap.add_argument("--error-on-new", action="store_true",
                    help="exit 1 on new findings (this is already the "
                    "default; the flag documents intent in CI)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code in sorted(RULES):
            r = RULES[code]
            print(f"{code}  [{r.severity:7s}] {r.summary}")
        return 0

    select = None
    if args.select:
        select = [c.strip().upper() for chunk in args.select
                  for c in chunk.split(",") if c.strip()]
        unknown = [c for c in select if c not in RULES]
        if unknown:
            print(f"drone_lint: unknown rule(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    paths = args.paths or [os.path.join(ROOT, "src", "repro")]
    paths = [p if os.path.isabs(p) else os.path.join(ROOT, p)
             if not os.path.exists(p) else p for p in paths]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"drone_lint: no such path: {', '.join(missing)}",
              file=sys.stderr)
        return 2

    findings = analyze_paths(paths, select=select, relative_to=ROOT)

    if args.update_baseline:
        write_baseline(args.baseline, findings)
        print(f"drone_lint: baseline updated with {len(findings)} "
              f"finding(s) -> {os.path.relpath(args.baseline, ROOT)}")
        return 0

    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    new = baseline_delta(findings, baseline)
    for f in new:
        print(f.render())
    absorbed = len(findings) - len(new)
    mode = "strict" if args.no_baseline else "baseline"
    print(f"drone_lint [{mode}]: {len(findings)} finding(s), "
          f"{absorbed} baselined, {len(new)} new")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
