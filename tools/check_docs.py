"""Docs link-and-reference check: every code path / symbol a markdown doc
mentions must exist in the repo, so README.md and docs/*.md cannot rot
silently as the code moves (run in CI next to the tier-1 tests).

    python tools/check_docs.py            # checks README.md + docs/*.md

What is checked (inline ``code`` spans only — fenced example blocks are
illustrative and skipped):

  - repo paths (``docs/API.md``, ``core/engine.py`` — also resolved under
    ``src/repro/``), including ``path::test`` / ``path:symbol`` anchors,
    whose symbol must appear as a def/class/assignment in that file;
  - dotted ``repro.*`` references: the module must exist; a trailing
    non-module component must be defined in the module's source;
  - ``TitleCase`` names (optionally ``TitleCase.attr``): the class must be
    defined somewhere in ``src/``, and ``attr`` must occur in that file;
  - ``name()`` call mentions: a ``def name`` must exist in the repo.

Anything else (flags, shell fragments, format sketches like ``[P, v_max]``)
is deliberately not interpreted. Names from the *source papers'* APIs
(cited in the paper-to-code docs but intentionally absent from the repo)
go in ``EXTERNAL_NAMES`` below instead of being reworded out of the docs.
"""
from __future__ import annotations

import glob
import os
import re
import sys

# Paper / external-system API names the docs may cite in code spans without
# a corresponding definition in this repo (DRONE §5.1, GoFFish, Pregel).
EXTERNAL_NAMES = {
    "getDegree", "addPairToVector", "voteToHalt", "Compute",
}

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
CODE_DIRS = ("src", "benchmarks", "examples", "tests", "tools")
PATH_EXTS = (".py", ".md", ".yml", ".yaml", ".json", ".txt")

# Directories whose every module must be *referenced* by at least one doc
# (reverse coverage: the docs check also fails when load-bearing code is
# undocumented, not only when docs point at vanished code). The kernels
# became load-bearing with the edge-compute backends — keep them covered.
COVERED_MODULE_DIRS = ("src/repro/kernels", "src/repro/core",
                       "src/repro/serving", "src/repro/analysis",
                       "src/repro/partition", "src/repro/algos")

_span = re.compile(r"`([^`]+)`")
_fence = re.compile(r"^(```|~~~)")
_dotted = re.compile(r"^repro(\.\w+)+$")
_classy = re.compile(r"^[A-Z]\w*(\.\w+)?$")
_call = re.compile(r"^(\w+)\(\)$")


def _iter_inline_spans(path):
    in_fence = False
    for ln, line in enumerate(open(path, encoding="utf-8"), 1):
        if _fence.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _span.finditer(line):
            yield ln, m.group(1).strip()


def _source_files():
    out = []
    for d in CODE_DIRS:
        out += glob.glob(os.path.join(ROOT, d, "**", "*.py"), recursive=True)
    return out


_SOURCES = None


def _sources():
    global _SOURCES
    if _SOURCES is None:
        _SOURCES = {f: open(f, encoding="utf-8").read()
                    for f in _source_files()}
    return _SOURCES


def _defined_in(text, name):
    return re.search(
        rf"^\s*(def|class)\s+{re.escape(name)}\b"
        rf"|^\s*{re.escape(name)}\s*[:=]", text, re.M) is not None


def _mentions(text, name):
    return re.search(rf"\b{re.escape(name)}\b", text) is not None


def _resolve_path(token):
    """Existing file for a path-like token, or None."""
    for base in (ROOT, os.path.join(SRC, "repro"), SRC):
        p = os.path.join(base, token)
        if os.path.exists(p):
            return p
    return None


def _check_path(token):
    sym = None
    if "::" in token:
        token, sym = token.split("::", 1)
    elif token.endswith(".py") is False and token.count(":") == 1 \
            and token.rsplit(":", 1)[0].endswith(".py"):
        token, sym = token.rsplit(":", 1)
    p = _resolve_path(token)
    if p is None:
        return f"path does not exist: {token}"
    if sym:
        text = open(p, encoding="utf-8").read()
        if not _defined_in(text, sym.split("[", 1)[0]):
            return f"{token} does not define {sym!r}"
    return None


def _check_dotted(token):
    parts = token.split(".")
    mod_path = os.path.join(SRC, *parts)
    if os.path.isdir(mod_path) or os.path.exists(mod_path + ".py"):
        return None                               # a module / package
    mod, sym = parts[:-1], parts[-1]
    base = os.path.join(SRC, *mod)
    for cand in (base + ".py", os.path.join(base, "__init__.py")):
        if os.path.exists(cand):
            text = open(cand, encoding="utf-8").read()
            if _defined_in(text, sym) or _mentions(text, sym):
                return None
            return f"{'.'.join(mod)} does not define {sym!r}"
    return f"module {'.'.join(mod)} does not exist"


def _check_classy(token):
    name, _, attr = token.partition(".")
    hits = [f for f, text in _sources().items()
            if re.search(rf"^\s*class\s+{name}\b", text, re.M)]
    if not hits:
        # not a class in this repo (e.g. `True`, `None`, jax types): only
        # flag TitleCase names that LOOK like ours but vanished — i.e.
        # nothing. Unknown names are skipped to avoid false positives on
        # external symbols.
        return None
    if attr and not any(_mentions(_sources()[f], attr) for f in hits):
        return f"class {name} exists but {attr!r} is not mentioned in its module"
    return None


def _check_call(name):
    for text in _sources().values():
        if re.search(rf"^\s*def\s+{name}\b", text, re.M):
            return None
    return f"no `def {name}` anywhere in the repo"


def check_token(token):
    token = token.rstrip(".,;:").strip()
    if not token or any(c in token for c in "<>*{}$| "):
        return None
    if token.rstrip("()").split(".")[0] in EXTERNAL_NAMES:
        return None
    if "/" in token:
        head = token.split()[0]
        if head.split("::")[0].split(":")[0].endswith(PATH_EXTS) \
                or _resolve_path(head) is not None:
            return _check_path(head)
        return None
    if _dotted.match(token):
        return _check_dotted(token)
    m = _call.match(token)
    if m:
        return _check_call(m.group(1))
    if _classy.match(token) and not token.isupper():
        return _check_classy(token)
    return None


def check_module_coverage(all_spans):
    """Every module under ``COVERED_MODULE_DIRS`` must be mentioned in at
    least one doc — by path (``kernels/bsp_spmv.py``) or dotted name
    (``repro.kernels.bsp_spmv``). Matches are word-bounded so a mention of
    ``bsp_ops.py`` can never count as covering ``ops.py``."""
    blob = " ".join(all_spans)
    errors = []
    for d in COVERED_MODULE_DIRS:
        for f in sorted(glob.glob(os.path.join(ROOT, d, "*.py"))):
            name = os.path.basename(f)
            if name == "__init__.py":
                continue
            dotted = os.path.relpath(f, SRC)[:-3].replace(os.sep, ".")
            pat = (rf"(^|[^\w.-]){re.escape(name)}\b"
                   rf"|(^|[^\w.-]){re.escape(dotted)}\b")
            if re.search(pat, blob):
                continue
            rel = os.path.relpath(f, ROOT)
            errors.append(
                f"{rel}: module is not referenced by any doc "
                f"(mention `{os.path.relpath(f, os.path.join(ROOT, 'src', 'repro'))}`"
                f" or `{dotted}` in README.md / docs/*.md)")
    return errors


def main():
    docs = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    readme = os.path.join(ROOT, "README.md")
    if os.path.exists(readme):
        docs.insert(0, readme)
    if not docs:
        print("check_docs: no markdown files found", file=sys.stderr)
        return 1
    errors = []
    n_checked = 0
    all_spans = []
    for doc in docs:
        for ln, token in _iter_inline_spans(doc):
            all_spans.append(token)
            err = check_token(token)
            n_checked += 1
            if err:
                rel = os.path.relpath(doc, ROOT)
                errors.append(f"{rel}:{ln}: `{token}` — {err}")
    errors += check_module_coverage(all_spans)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs: {len(docs)} files, {n_checked} spans, "
          f"{len(errors)} broken references")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
