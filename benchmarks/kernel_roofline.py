"""Kernel-layer roofline: the SVHM local sweep as (a) XLA gather/scatter,
(b) windowed one-hot segment-combine (Pallas, MXU for sums), (c) dense-tile
block-sparse SpMV (Pallas) — modeled v5e time per sweep from the layouts'
actual byte/FLOP footprints on a real Kronecker partition. Correctness of
both kernels vs the jnp oracle is asserted (interpret mode) on a subsample.

This is the dry-run-style profile for the kernel layer: CPU wall-times of
interpret mode are meaningless, the *layout-derived* roofline terms are the
deliverable (DESIGN.md §5).
"""
from __future__ import annotations

import numpy as np

from repro.core import partition_and_build
from repro.graphgen import kronecker_graph
from repro.kernels import ops
from repro.kernels.bsp_spmv import TM, TN

from benchmarks.common import save, table

HBM_BW = 819e9
PEAK = 197e12


def run(scale: str = "small"):
    g = kronecker_graph(13 if scale == "small" else 16, seed=3, weighted=True)
    pg = partition_and_build(g, 16, "cdbh")
    p = int(np.argmax(pg.edges_per_part))          # busiest partition
    m = pg.emask[p]
    src = pg.esrc[p][m].astype(np.int64)
    dst = pg.edst[p][m].astype(np.int64)
    w = pg.ew[p][m]
    nv = int(pg.vertices_per_part[p])
    ne = src.shape[0]

    # (a) XLA scatter path: read vals[src] (gather 4B) + edge ids (8B) +
    #     weights (4B) + scatter-combine writes (read+write 8B per edge)
    bytes_scatter = ne * (4 + 8 + 4 + 8) + nv * 8

    # (b) windowed one-hot kernel: edge messages (padded) + local_dst +
    #     out windows; FLOPs = onehot matmul 2*Be*W per block
    wl = ops.window_align_edges(dst, nv, block_edges=512)
    padded = wl.n_blocks * wl.block_edges
    bytes_window = padded * (4 + 4) + wl.n_windows * 128 * 4 * 2 + ne * 4
    flops_window = 2.0 * padded * 128

    # (c) dense-tile SpMV: tile bytes dominate; MXU flops 2*TM*TN per tile
    tl = ops.build_tiles(src, dst, w, nv, nv, "plus_times")
    ntiles = tl.tiles.shape[0]
    bytes_tiles = ntiles * TM * TN * 4 + ntiles * (TN + TM) * 4
    flops_tiles = 2.0 * ntiles * TM * TN

    rows = [
        ["xla-scatter", ne, "-", f"{bytes_scatter/2**20:.1f}",
         f"{bytes_scatter/HBM_BW*1e6:.1f}", "-", "serializing scatter"],
        ["windowed-onehot", padded, wl.n_blocks,
         f"{bytes_window/2**20:.1f}", f"{bytes_window/HBM_BW*1e6:.1f}",
         f"{flops_window/PEAK*1e6:.2f}", "MXU segment-sum"],
        ["dense-tiles", ntiles, f"density={tl.density:.4f}",
         f"{bytes_tiles/2**20:.1f}", f"{bytes_tiles/HBM_BW*1e6:.1f}",
         f"{flops_tiles/PEAK*1e6:.2f}",
         ("HBM-competitive (density>~1/3)" if tl.density > 1 / 3 else
          "too sparse for dense tiles -> use windowed-onehot")],
    ]
    table("Kernel roofline — one SVHM sweep on the busiest CDBH partition "
          f"({ne} edges, {nv} vertices)",
          ["impl", "units", "blocks", "MiB moved", "HBM µs", "MXU µs",
           "note"], rows)

    # correctness spot-check, interpret mode, subsample
    k = min(ne, 20_000)
    vals = np.random.default_rng(0).uniform(0, 2, (nv, 1)).astype(np.float32)
    got = np.asarray(ops.spmv(src[:k], dst[:k], w[:k], vals, nv,
                              semiring="plus_times", kernel="windowed"))
    dense = np.zeros((nv,), np.float32)
    np.add.at(dense, dst[:k], w[:k] * vals[src[:k], 0])
    np.testing.assert_allclose(got[:, 0], dense, rtol=2e-4, atol=2e-4)

    return save("kernel_roofline", {
        "edges": ne, "vertices": nv,
        "scatter_bytes": bytes_scatter,
        "window": dict(blocks=int(wl.n_blocks), padded_edges=int(padded),
                       bytes=bytes_window, flops=flops_window),
        "tiles": dict(n=int(ntiles), density=float(tl.density),
                      bytes=bytes_tiles, flops=flops_tiles),
    })


if __name__ == "__main__":
    run()
