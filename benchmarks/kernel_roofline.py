"""Kernel-layer roofline: the SVHM local sweep as (a) XLA gather/scatter,
(b) windowed one-hot segment-combine (Pallas, MXU for sums), (c) dense-tile
block-sparse SpMV (Pallas) — modeled v5e time per sweep from the layouts'
actual byte/FLOP footprints on a real Kronecker partition. Correctness of
both kernels vs the jnp oracle is asserted (interpret mode) on a subsample.

This is the dry-run-style profile for the kernel layer: CPU wall-times of
interpret mode are meaningless, the *layout-derived* roofline terms are the
deliverable (DESIGN.md §5).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import autotune, partition_and_build
from repro.graphgen import kronecker_graph
from repro.kernels import ops
from repro.kernels.bsp_spmv import TM, TN

from benchmarks.common import save, table

HBM_BW = 819e9
PEAK = 197e12


def run(scale: str = "small"):
    g = kronecker_graph(13 if scale == "small" else 16, seed=3, weighted=True)
    pg = partition_and_build(g, 16, "cdbh")
    p = int(np.argmax(pg.edges_per_part))          # busiest partition
    m = pg.emask[p]
    src = pg.esrc[p][m].astype(np.int64)
    dst = pg.edst[p][m].astype(np.int64)
    w = pg.ew[p][m]
    nv = int(pg.vertices_per_part[p])
    ne = src.shape[0]

    # (a) XLA scatter path: read vals[src] (gather 4B) + edge ids (8B) +
    #     weights (4B) + scatter-combine writes (read+write 8B per edge)
    bytes_scatter = ne * (4 + 8 + 4 + 8) + nv * 8

    # (b) windowed one-hot kernel: edge messages (padded) + local_dst +
    #     out windows; FLOPs = onehot matmul 2*Be*W per block
    wl = ops.window_align_edges(dst, nv, block_edges=512)
    padded = wl.n_blocks * wl.block_edges
    bytes_window = padded * (4 + 4) + wl.n_windows * 128 * 4 * 2 + ne * 4
    flops_window = 2.0 * padded * 128

    # (c) dense-tile SpMV: tile bytes dominate; MXU flops 2*TM*TN per tile
    tl = ops.build_tiles(src, dst, w, nv, nv, "plus_times")
    ntiles = tl.tiles.shape[0]
    bytes_tiles = ntiles * TM * TN * 4 + ntiles * (TN + TM) * 4
    flops_tiles = 2.0 * ntiles * TM * TN

    rows = [
        ["xla-scatter", ne, "-", f"{bytes_scatter/2**20:.1f}",
         f"{bytes_scatter/HBM_BW*1e6:.1f}", "-", "serializing scatter"],
        ["windowed-onehot", padded, wl.n_blocks,
         f"{bytes_window/2**20:.1f}", f"{bytes_window/HBM_BW*1e6:.1f}",
         f"{flops_window/PEAK*1e6:.2f}", "MXU segment-sum"],
        ["dense-tiles", ntiles, f"density={tl.density:.4f}",
         f"{bytes_tiles/2**20:.1f}", f"{bytes_tiles/HBM_BW*1e6:.1f}",
         f"{flops_tiles/PEAK*1e6:.2f}",
         ("HBM-competitive (density>~1/3)" if tl.density > 1 / 3 else
          "too sparse for dense tiles -> use windowed-onehot")],
    ]
    table("Kernel roofline — one SVHM sweep on the busiest CDBH partition "
          f"({ne} edges, {nv} vertices)",
          ["impl", "units", "blocks", "MiB moved", "HBM µs", "MXU µs",
           "note"], rows)

    # correctness spot-check, interpret mode, subsample
    k = min(ne, 20_000)
    vals = np.random.default_rng(0).uniform(0, 2, (nv, 1)).astype(np.float32)
    got = np.asarray(ops.spmv(src[:k], dst[:k], w[:k], vals, nv,
                              semiring="plus_times", kernel="windowed"))
    dense = np.zeros((nv,), np.float32)
    np.add.at(dense, dst[:k], w[:k] * vals[src[:k], 0])
    np.testing.assert_allclose(got[:, 0], dense, rtol=2e-4, atol=2e-4)

    return save("kernel_roofline", {
        "edges": ne, "vertices": nv,
        "scatter_bytes": bytes_scatter,
        "window": dict(blocks=int(wl.n_blocks), padded_edges=int(padded),
                       bytes=bytes_window, flops=flops_window),
        "tiles": dict(n=int(ntiles), density=float(tl.density),
                      bytes=bytes_tiles, flops=flops_tiles),
    })


def crossover(smoke: bool = False):
    """Backend-crossover sweep: for every calibrated density point of the
    platform's autotune table, report each backend's fitted sweep latency
    next to the ``edge_backend='auto'`` pick. With ``smoke`` the pick is
    asserted never slower than the worst manual backend at any point — the
    guardrail CI runs against the shipped policy."""
    tbl = autotune.get_table()
    backends = autotune.BACKEND_ORDER
    rows, out = [], []
    for p in tbl.points:
        kw = dict(n_edges=[p["n_edges"]], n_vertices=p["n_vertices"],
                  n_tiles=[p["n_tiles"]], n_blocks=[p["n_blocks"]],
                  n_windows=p["n_windows"])
        fitted = {b: float(c[0])
                  for b, c in tbl.partition_costs(**kw).items()}
        (pick,) = tbl.pick(**kw)
        sampled = {"coo": p["cost_coo"], "pallas_tiles": p["cost_tiles"],
                   "pallas_windows": p["cost_windows"]}
        rows.append([p["n_vertices"], p["n_edges"],
                     f"{p['density']:.4f}"]
                    + [f"{fitted[b]*1e6:.2f}" for b in backends]
                    + [pick])
        out.append(dict(n_vertices=p["n_vertices"], n_edges=p["n_edges"],
                        density=p["density"], pick=pick,
                        fitted_us={b: fitted[b] * 1e6 for b in backends},
                        sampled_us={b: sampled[b] * 1e6 for b in backends}))
        if smoke:
            worst = max(sampled.values())
            assert sampled[pick] <= worst * (1.0 + 1e-9), (
                f"auto picked {pick} ({sampled[pick]:.3e}s) but the worst "
                f"manual backend costs {worst:.3e}s at density "
                f"{p['density']:.4f}")

    table(f"Edge-backend crossover — {tbl.source} calibration "
          f"({tbl.platform}), fitted µs per sweep",
          ["nv", "edges", "density"] + [f"{b} µs" for b in backends]
          + ["auto pick"], rows)
    picked = {b: sum(1 for o in out if o["pick"] == b) for b in backends}
    print(f"picks: {picked}" + ("  [smoke: pick never worst — OK]"
                                if smoke else ""))
    return save("kernel_crossover", {
        "platform": tbl.platform, "source": tbl.source,
        "unit_costs": tbl.unit_costs, "points": out, "picks": picked,
    })


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="small", choices=("small", "large"))
    ap.add_argument("--crossover", action="store_true",
                    help="sweep the calibrated density grid and report "
                         "per-backend latency plus the auto policy's pick")
    ap.add_argument("--smoke", action="store_true",
                    help="with --crossover: assert the auto pick is never "
                         "slower than the worst manual backend")
    a = ap.parse_args()
    if a.crossover:
        crossover(smoke=a.smoke)
    else:
        run(scale=a.scale)
