"""Algorithm-suite benchmark: fresh vs incremental superstep counts.

Two parts:

  1. a fresh-run table — supersteps, local sweeps, wall time for every
     suite algorithm on a canonical power-law graph;
  2. the incremental table — for each monotone variant (BFS and label
     propagation under inserts, k-core under deletes), the supersteps a
     warm restart needs after a delta flush vs a cold recompute of the
     same post-delta graph. The scenario graph is a cycle (a surviving
     2-core) with a long pendant path whose edges interleave across all
     partitions in small blocks, so a cold run *must* cascade across
     partition hand-offs superstep by superstep while the warm restart
     answers from the previous fixpoint.

``--smoke`` (the CI ``algo-suite`` job) shrinks the sizes and *asserts*
every incremental variant converges in strictly fewer supersteps than the
fresh recompute — the suite's headline incremental guarantee.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.algos import (BFS, LabelPropagation, make_kcore, make_msbfs,
                         make_triangles)
from repro.core import build_partitioned_graph, partition_and_build
from repro.core.graph import Graph
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession
from repro.stream.ingest import StreamContext

from benchmarks.common import save, table


# --------------------------------------------------------------------- #
def _canonical(g):
    return g.drop_self_loops().dedup().as_undirected()


def _cycle_with_pendant(n_cycle, n_pendant, n_parts, block_pairs):
    """Cycle 0..n_cycle-1 plus a pendant path hanging off vertex 0, with
    undirected pairs assigned to partitions in round-robin blocks — the
    partition-crossing cascade a cold peel/sweep must pay for."""
    n = n_cycle + n_pendant
    cu = np.arange(n_cycle, dtype=np.int64)
    cv = np.concatenate([cu[1:], cu[:1]])
    pu = np.concatenate([[0], np.arange(n_cycle, n - 1)]).astype(np.int64)
    pv = np.arange(n_cycle, n, dtype=np.int64)
    u = np.concatenate([cu, pu])
    v = np.concatenate([cv, pv])
    src = np.concatenate([np.stack([u, v], 1).ravel()])
    dst = np.concatenate([np.stack([v, u], 1).ravel()])
    pair_id = np.repeat(np.arange(u.size), 2)
    part = ((pair_id // block_pairs) % n_parts).astype(np.int32)
    g = Graph(n, src, dst, np.ones(src.size, np.float32), directed=True)
    pg = build_partitioned_graph(g, part, n_parts)
    ctx = StreamContext("rh-vc", n_parts, 0, n, np.zeros(n, np.int64))
    return g, pg, ctx


def _fresh_table(n, n_parts, seed=3):
    g = _canonical(powerlaw_graph(n, seed=seed))
    pg = partition_and_build(g, n_parts, "cdbh")
    pivots = np.unique(np.array([0, n // 3, n // 2, n - 1]))
    sess = GraphSession(pg)
    rows, rec = [], {}
    try:
        for name, prog, params in [
                ("bfs", BFS(), {"source": 0}),
                ("msbfs", *make_msbfs(pivots)),
                ("lp", LabelPropagation(hops=3), {}),
                ("kcore", *make_kcore(2)),
                ("triangles", *make_triangles(pivots))]:
            sess.query(prog, params)                  # compile
            t0 = time.perf_counter()
            _, st = sess.query(prog, params, warm=False,
                               use_result_cache=False)
            dt = time.perf_counter() - t0
            rows.append([name, st.supersteps, st.processed_edges,
                         f"{dt * 1e3:.1f}"])
            rec[name] = {"supersteps": st.supersteps,
                         "processed_edges": st.processed_edges, "ms": dt * 1e3}
    finally:
        sess.close()
    table(f"fresh runs (powerlaw n={n}, P={n_parts})",
          ["algo", "supersteps", "processed_edges", "ms"], rows)
    return rec


def _incremental(scale):
    p = {"smoke": dict(n_cycle=48, n_pendant=150, P=4, block=4),
         "small": dict(n_cycle=96, n_pendant=400, P=4, block=4),
         "large": dict(n_cycle=192, n_pendant=1200, P=8, block=4)}[scale]
    rows, rec = [], {}

    # inserts: BFS + LP. One pendant leaf appended near the cycle — a
    # local change the warm fixpoint absorbs in O(1) supersteps.
    for name, mk in [("bfs", lambda: (BFS(), {"source": 0})),
                     ("lp", lambda: (LabelPropagation(hops=3), {}))]:
        g, pg, ctx = _cycle_with_pendant(p["n_cycle"], p["n_pendant"],
                                         p["P"], p["block"])
        sess = GraphSession(pg, ctx=ctx)
        try:
            prog, params = mk()
            sess.query(prog, params)
            nv = sess.pg.n_vertices
            sess.update(adds=([5, nv], [nv, 5], [1.0, 1.0]))
            sess.flush()
            _, st_w = sess.query(prog, params, warm=True)
            _, st_c = sess.query(prog, params, warm=False,
                                 use_result_cache=False)
        finally:
            sess.close()
        rows.append([name, "insert", st_w.supersteps, st_c.supersteps])
        rec[name] = {"delta": "insert", "warm": st_w.supersteps,
                     "fresh": st_c.supersteps}

    # deletes: k-core. Cutting one cycle edge unravels the (small) cycle;
    # the warm peel re-kills the long pendant from memory and only pays
    # for the newly dead cycle, while a cold run re-cascades everything.
    g, pg, ctx = _cycle_with_pendant(p["n_cycle"], p["n_pendant"],
                                     p["P"], p["block"])
    sess = GraphSession(pg, ctx=ctx)
    try:
        prog, params = make_kcore(2)
        sess.query(prog, params)
        sess.update(deletes=([1, 2], [2, 1]))
        sess.flush()
        _, st_w = sess.query(prog, params, warm=True)
        _, st_c = sess.query(prog, params, warm=False,
                             use_result_cache=False)
    finally:
        sess.close()
    rows.append(["kcore", "delete", st_w.supersteps, st_c.supersteps])
    rec["kcore"] = {"delta": "delete", "warm": st_w.supersteps,
                    "fresh": st_c.supersteps}

    table(f"incremental vs fresh after one flush ({scale})",
          ["algo", "delta", "warm supersteps", "fresh supersteps"], rows)
    return rec


def run(scale="small"):
    fresh = _fresh_table({"smoke": 200, "small": 600, "large": 2000}[scale],
                         4 if scale != "large" else 8)
    inc = _incremental(scale)
    for name, r in inc.items():
        assert r["warm"] < r["fresh"], \
            (f"{name}: incremental took {r['warm']} supersteps, fresh "
             f"{r['fresh']} — the warm restart must win strictly")
    print("incremental < fresh for every monotone variant")
    name = "algo_suite" + ("_smoke" if scale == "smoke" else "")
    save(name, {"scale": scale, "fresh": fresh, "incremental": inc})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=("small", "large", "smoke"))
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run with the strict incremental assert")
    a = ap.parse_args()
    run("smoke" if a.smoke else a.scale)


if __name__ == "__main__":
    main()
