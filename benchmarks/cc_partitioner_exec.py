"""Paper Fig. 4 — execution metrics of the partitioner choice: CC runtime,
supersteps and (key,value) messages per superstep under RH vs CDBH vs EBV
vertex-cut (WebBase proxied by a Kronecker power-law graph).

``--smoke`` runs the CI-sized variant: a smaller Kronecker graph, same
assertions — replication-aware partitioners (cdbh, ebv) must not move more
messages than the random hash.
"""
from __future__ import annotations

from repro.algos import ConnectedComponents
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.graphgen import kronecker_graph

from benchmarks.common import save, table


def run(scale: str = "small"):
    k = {"smoke": 12, "small": 14, "large": 18}[scale]
    g = kronecker_graph(k, seed=2)
    p = 8 if scale == "smoke" else 16
    rows, recs = [], {}
    for pname in ("rh-vc", "cdbh", "ebv"):
        pg = partition_and_build(g, p, pname)
        cfg = EngineConfig(mode="sc", trace=True)
        res, st = run_sim(ConnectedComponents(), pg, None, cfg)
        rows.append([pname, st.supersteps, st.total_messages,
                     f"{st.wall_time:.2f}s", st.messages_per_step[:8]])
        recs[pname] = dict(supersteps=st.supersteps,
                           total_messages=st.total_messages,
                           wall_time=st.wall_time,
                           messages_per_step=st.messages_per_step)
    table("Fig 4 — CC execution vs partitioner (kronecker power-law)",
          ["partitioner", "supersteps", "messages", "time",
           "msgs/step (first 8)"], rows)
    # paper: replication-aware partitioners move fewer (key,value) messages
    # than RH on power-law — fewer replicas means fewer mirror updates
    assert recs["cdbh"]["total_messages"] <= recs["rh-vc"]["total_messages"]
    assert recs["ebv"]["total_messages"] <= recs["rh-vc"]["total_messages"]
    name = "cc_partitioner_exec" + ("_smoke" if scale == "smoke" else "")
    return save(name, {"graph_edges": g.n_edges, "n_parts": p,
                       "scale": scale, **recs})


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=("small", "large", "smoke"))
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (kron-12, P=8), same assertions")
    a = ap.parse_args()
    run("smoke" if a.smoke else a.scale)
