"""Paper Fig. 4 — execution metrics of the partitioner choice: CC runtime,
supersteps and (key,value) messages per superstep under RH vs CDBH vertex-cut
(WebBase proxied by a Kronecker power-law graph)."""
from __future__ import annotations

from repro.algos import ConnectedComponents
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.graphgen import kronecker_graph

from benchmarks.common import save, table


def run(scale: str = "small"):
    g = kronecker_graph(14 if scale == "small" else 18, seed=2)
    p = 16
    rows, recs = [], {}
    for pname in ("rh-vc", "cdbh"):
        pg = partition_and_build(g, p, pname)
        cfg = EngineConfig(mode="sc", trace=True)
        res, st = run_sim(ConnectedComponents(), pg, None, cfg)
        rows.append([pname, st.supersteps, st.total_messages,
                     f"{st.wall_time:.2f}s", st.messages_per_step[:8]])
        recs[pname] = dict(supersteps=st.supersteps,
                           total_messages=st.total_messages,
                           wall_time=st.wall_time,
                           messages_per_step=st.messages_per_step)
    table("Fig 4 — CC execution vs partitioner (kronecker power-law)",
          ["partitioner", "supersteps", "messages", "time",
           "msgs/step (first 8)"], rows)
    # paper: CDBH fewer messages + <= supersteps than RH on power-law
    assert recs["cdbh"]["total_messages"] <= recs["rh-vc"]["total_messages"]
    return save("cc_partitioner_exec",
                {"graph_edges": g.n_edges, "n_parts": p, **recs})


if __name__ == "__main__":
    run()
