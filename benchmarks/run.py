"""Benchmark harness entry (deliverable d) — one benchmark per paper
table/figure. ``python -m benchmarks.run [--scale small|large]``.

  Table 3  -> partitioner_metrics     Fig 4 -> cc_partitioner_exec
  Fig 5    -> strong_scaling          Table 4/Fig 6-7 -> sssp_variants
  Fig 8    -> breakdown               Fig 9 -> weak_scaling
  §8.5 trillion-edge claim -> trillion_dryrun (compile-only, if artifact
  present)

Results land in results/bench/*.json; tables print to stdout.
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (algo_suite, breakdown, cc_partitioner_exec,
                        kernel_roofline, partitioner_metrics, sssp_variants,
                        strong_scaling, trillion_dryrun, weak_scaling)

SUITES = [
    ("partitioner_metrics", partitioner_metrics.run),
    ("cc_partitioner_exec", cc_partitioner_exec.run),
    ("strong_scaling", strong_scaling.run),
    ("sssp_variants", sssp_variants.run),
    ("breakdown", breakdown.run),
    ("weak_scaling", weak_scaling.run),
    ("kernel_roofline", kernel_roofline.run),
    ("algo_suite", algo_suite.run),
    ("trillion_dryrun", trillion_dryrun.run),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "large"])
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = []
    for name, fn in SUITES:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn(args.scale) if name != "trillion_dryrun" else fn()
            print(f"[bench ok] {name} ({time.time()-t0:.1f}s)", flush=True)
        except Exception:
            failures.append(name)
            print(f"[bench FAIL] {name}\n{traceback.format_exc()[-1500:]}",
                  flush=True)
    if failures:
        raise SystemExit(f"failed: {failures}")
    print("all benchmarks passed")


if __name__ == "__main__":
    main()
