"""Paper Table 3 — partitioning metrics (Imbalance, Replication Factor) of
Random-Hash vs Canonical Degree-Based Hashing vs streaming EBV vertex-cut on
power-law graphs (+ the edge-cut baseline and the grid vertex-cut for
context).

``--smoke`` runs the CI-sized variant (docs/PARTITIONING.md): one skewed
power-law graph at P=8, asserting the EBV acceptance bar — replication
factor strictly below rh-vc with edge imbalance <= 1.1.
"""
from __future__ import annotations

import time

from repro.core import PARTITIONERS, build_partitioned_graph, partition_metrics
from repro.graphgen import kronecker_graph, powerlaw_graph

from benchmarks.common import save, table


def _measure(gname, g, p, pnames, rows, records):
    for pname in pnames:
        t0 = time.time()
        part = PARTITIONERS[pname](g, p, seed=0)
        t_part = time.time() - t0
        pg = build_partitioned_graph(g, part, p)
        m = partition_metrics(pg)
        rows.append([gname, p, pname, f"{m.imbalance:.4f}",
                     f"{m.replication_factor:.4f}", m.n_frontier,
                     f"{m.master_balance:.3f}", f"{t_part:.2f}s"])
        records.append(dict(graph=gname, n_parts=p, partitioner=pname,
                            imbalance=m.imbalance,
                            replication_factor=m.replication_factor,
                            n_frontier=m.n_frontier,
                            master_balance=m.master_balance,
                            partition_time_s=t_part,
                            n_edges=g.n_edges, n_vertices=g.n_vertices))


def run(scale: str = "small"):
    if scale == "smoke":
        return run_smoke()
    cases = {
        # (graph_name, graph, n_parts) — LiveJournal/WebBase proxies
        "small": [("powerlaw-50k", powerlaw_graph(50_000, alpha=2.2,
                                                  avg_degree=14, seed=0
                                                  ).as_undirected(), 4),
                  ("kron-16", kronecker_graph(16, seed=1), 32)],
        "large": [("powerlaw-500k", powerlaw_graph(500_000, alpha=2.2,
                                                   avg_degree=14, seed=0
                                                   ).as_undirected(), 4),
                  ("kron-20", kronecker_graph(20, seed=1), 32)],
    }[scale]

    rows, records = [], []
    for gname, g, p in cases:
        _measure(gname, g, p, ("rh-vc", "cdbh", "ebv", "grid", "rh-ec"),
                 rows, records)
    table("Table 3 — partitioner metrics (RH vs CDBH vs EBV vertex-cut)",
          ["graph", "P", "partitioner", "imbalance", "repl.factor",
           "frontier", "master_bal", "t_part"], rows)
    # paper claim: CDBH RF <= RH RF on power-law graphs; the streaming EBV
    # router must hold the same bar (it optimizes RF directly)
    for gname in {r[0] for r in rows}:
        rf = {r[2]: float(r[4]) for r in rows if r[0] == gname}
        assert rf["cdbh"] <= rf["rh-vc"] * 1.02, (gname, rf)
        assert rf["ebv"] <= rf["rh-vc"] * 1.02, (gname, rf)
    return save("partitioner_metrics", {"rows": records, "scale": scale})


def run_smoke():
    """CI gate: EBV acceptance bar on one skewed power-law graph."""
    g = powerlaw_graph(20_000, alpha=2.1, avg_degree=8, seed=0)
    rows, records = [], []
    _measure("powerlaw-20k", g, 8, ("rh-vc", "cdbh", "ebv"), rows, records)
    table("partitioner metrics (smoke, P=8)",
          ["graph", "P", "partitioner", "imbalance", "repl.factor",
           "frontier", "master_bal", "t_part"], rows)
    by = {r["partitioner"]: r for r in records}
    # acceptance (ISSUE / docs/PARTITIONING.md): strictly lower RF than the
    # stateless hash router AND edge imbalance within 1.1
    assert by["ebv"]["replication_factor"] < by["rh-vc"]["replication_factor"], by
    assert by["ebv"]["imbalance"] <= 1.1, by
    return save("partitioner_metrics_smoke", {"rows": records,
                                              "scale": "smoke"})


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=("small", "large", "smoke"))
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run asserting the EBV acceptance bar")
    a = ap.parse_args()
    run("smoke" if a.smoke else a.scale)
