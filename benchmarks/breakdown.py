"""Paper Fig. 8 — execution-time breakdown: per-superstep computing vs
synchronization (SBS) time, and per-partition workload balance (min/max),
for DRONE-VC-CDBH vs DRONE-EC-RH on a power-law graph.

The compute and sync phases are jitted separately so the wall-clock split is
measurable on CPU; the per-partition *sweep counts* expose the straggler
skew the paper attributes to edge-cut imbalance.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.algos import ConnectedComponents
from repro.core import EngineConfig, partition_and_build
from repro.core import engine as E
from repro.core import sbs
from repro.graphgen import kronecker_graph

from benchmarks.common import save, table


def _instrumented_cc(pg, mode="sc", max_supersteps=10000):
    prog = ConnectedComponents()
    cfg = EngineConfig(mode=mode)
    sgs = E._device_subgraph(pg)
    n_slots, K = pg.n_slots, prog.payload
    ident = prog.identity
    ec = E.EdgeCombine(())
    ex = sbs.SimExchange()

    @jax.jit
    def local_all(state, merged_buf, first):
        merged_v = jax.vmap(lambda sg: sbs.gather_merged(merged_buf, sg.slot))(sgs)
        state, out, sweeps, last_ch = jax.vmap(
            lambda sg, st, m: E._local_phase(prog, sg, None, st, m, ec,
                                             cfg.local_bound, first)
        )(sgs, state, merged_v)
        return state, out, sweeps, last_ch

    @jax.jit
    def sync_all(out, last_out, last_ch):
        bufs, changed = jax.vmap(
            lambda sg, o, lo: E._pack(prog, sg, o, lo, n_slots))(sgs, out,
                                                                 last_out)
        merged = ex.all_combine(bufs, prog.combiner).at[n_slots].set(ident)
        return merged, jnp.sum(changed), jnp.sum(last_ch > 0), changed

    sweeps_total = np.zeros(pg.n_parts, np.int64)
    steps = 0
    state = jax.vmap(lambda sg: prog.init(sg, None, ec))(sgs)
    merged = jnp.full((n_slots + 1, K), ident, prog.dtype)
    last_out = jnp.full((pg.n_parts, pg.v_max, K), ident, prog.dtype)
    t_comp = t_sync = 0.0
    for step in range(max_supersteps):
        t0 = time.perf_counter()
        res = local_all(state, merged, jnp.bool_(step == 0))
        jax.block_until_ready(res)
        state, out, sweeps, last_ch = res
        t_comp += time.perf_counter() - t0
        t0 = time.perf_counter()
        merged, msgs, active, changed = jax.block_until_ready(
            sync_all(out, last_out, last_ch))
        t_sync += time.perf_counter() - t0
        last_out = out
        sweeps_total += np.asarray(sweeps, np.int64)
        steps = step + 1
        if int(msgs) == 0 and int(active) == 0:
            break
    epp = pg.edges_per_part.astype(np.int64)
    work = sweeps_total * epp
    return dict(supersteps=steps, compute_s=t_comp, sync_s=t_sync,
                work_min=int(work.min()), work_max=int(work.max()),
                work_mean=float(work.mean()),
                skew=float(work.max() / max(work.mean(), 1)))


def run(scale: str = "small"):
    g = kronecker_graph(13 if scale == "small" else 16, seed=5)
    rows, recs = [], {}
    for vname, pname in (("DRONE-VC-CDBH", "cdbh"), ("DRONE-VC-RH", "rh-vc"),
                         ("DRONE-EC-RH", "rh-ec")):
        pg = partition_and_build(g, 16, pname)
        r = _instrumented_cc(pg)
        rows.append([vname, r["supersteps"], f"{r['compute_s']:.2f}s",
                     f"{r['sync_s']:.2f}s", r["work_max"],
                     f"{r['skew']:.2f}x"])
        recs[vname] = r
    table("Fig 8 — CC breakdown: compute vs SBS sync, workload skew",
          ["variant", "supersteps", "compute", "sync", "max work",
           "skew(max/mean)"], rows)
    # paper: vertex-cut balances edge work better than RH edge-cut
    assert recs["DRONE-VC-CDBH"]["skew"] <= recs["DRONE-EC-RH"]["skew"] * 1.05
    return save("breakdown", recs)


if __name__ == "__main__":
    run()
