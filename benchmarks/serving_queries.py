"""Serving-path benchmark: cached vs cold query latency on one
``GraphSession`` over a Graph500 Kronecker graph.

Measures what the compiled-runner cache buys for continuous query traffic
(ROADMAP north star): the first (cold) query pays trace+compile once
(``ExecutionStats.compile_time``); every further query — same program,
different parameters, other algorithms already seen — runs at steady-state
latency with ``compile_time == 0``. Also times the update path: a
shape-preserving ``update+flush`` keeps the cache warm, so the post-update
query is patch + execute, no recompile.

``--grow`` adds the shape-bucket section (docs/ARCHITECTURE.md): interleaved
insert-flush/query cycles on a *growing* power-law graph, run once with the
bucketed ``ShapePolicy`` (session default) and once with
``ShapePolicy.exact()`` (the pre-bucket behavior), reporting per-cycle
recompile counts and p50 query latency. The bucketed session must reach a
steady state with **zero** recompiles per cycle while the exact session
recompiles on (nearly) every growth flush.

``--edge-backend`` selects the sweep's edge-compute backend
(``EngineConfig.edge_backend``) for every section; ``--edge-backend all``
adds a dedicated comparison section instead — the same streaming-growth
cycle on ``coo`` / ``pallas_tiles`` / ``pallas_windows``, asserting under
``--smoke`` that the Pallas backends (interpret mode on CPU) reach the same
zero-recompile steady state with bit-identical SSSP answers.

``--multi-tenant`` runs the closed-loop traffic-generator section INSTEAD of
the single-session sections (docs/SERVING.md): N same-size power-law graphs
in one ``SessionPool``, mixed SSSP/CC/PageRank streams per tenant pushed
through the ``MicroBatcher``, interleaved insert flushes plus a deleting
flush, reporting p50/p95/p99 end-to-end latency. With ``--smoke`` it is the
serving acceptance gate: sampled batched answers must equal direct
unbatched launches bit-identically (allclose for PageRank), compilations
must not scale with tenant count, and the tiered result cache must serve
repeats with zero device launches yet miss after the deleting flush.

    PYTHONPATH=src python -m benchmarks.serving_queries [--scale 14]
    PYTHONPATH=src python -m benchmarks.serving_queries --grow
    PYTHONPATH=src python -m benchmarks.serving_queries --edge-backend all
    PYTHONPATH=src python -m benchmarks.serving_queries \
        --smoke --grow --edge-backend all                             # CI
    PYTHONPATH=src python -m benchmarks.serving_queries \
        --smoke --multi-tenant                                        # CI
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save, table
from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.core import EngineConfig, ShapePolicy
from repro.graphgen import kronecker_graph, powerlaw_graph
from repro.serving import (BatchPolicy, DictStore, MicroBatcher,
                           ResultCache, SessionPool)
from repro.session import GraphSession

EDGE_BACKENDS = ("coo", "pallas_tiles", "pallas_windows", "auto")


def _quantiles(xs):
    """(p50, p95, p99) — serving latency is a tail story, not a mean."""
    xs = np.asarray(xs)
    return (float(np.median(xs)), float(np.percentile(xs, 95)),
            float(np.percentile(xs, 99)))


def bench_query_latency(sess, n_repeat, n_sources):
    """Cold-vs-cached latency per algorithm, plus source sweep on the one
    cached SSSP runner (the multi-tenant serving pattern)."""
    g_nv = sess.pg.n_vertices
    algos = [("sssp", SSSP(), {"source": 0}),
             ("cc", ConnectedComponents(), None),
             ("pagerank", PageRank(tol=1e-7), {"n_vertices": g_nv})]
    rows, recs = [], {}
    for name, prog, params in algos:
        _, st_cold = sess.query(prog, params, warm=False)
        assert st_cold.compile_time > 0.0, "first query must compile"
        hot = []
        for _ in range(n_repeat):
            _, st = sess.query(prog, params, warm=False)
            assert st.compile_time == 0.0, "repeat query must hit the cache"
            hot.append(st.wall_time)
        med, p95, p99 = _quantiles(hot)
        rows.append([name, f"{st_cold.compile_time:.2f}",
                     f"{st_cold.wall_time*1e3:.0f}", f"{med*1e3:.0f}",
                     f"{p95*1e3:.0f}", f"{p99*1e3:.0f}",
                     f"{st_cold.total_time / med:.1f}x"])
        recs[f"{name}_compile_s"] = st_cold.compile_time
        recs[f"{name}_cold_ms"] = st_cold.total_time * 1e3
        recs[f"{name}_hot_median_ms"] = med * 1e3
        recs[f"{name}_hot_p95_ms"] = p95 * 1e3
        recs[f"{name}_hot_p99_ms"] = p99 * 1e3
    table(f"Cold vs cached query latency ({n_repeat} repeats)",
          ["algo", "compile s", "first wall ms", "hot p50 ms", "hot p95 ms",
           "hot p99 ms", "cold/hot"], rows)

    # parameter sweep: every source reuses the one compiled SSSP runner
    rng = np.random.default_rng(0)
    lat = []
    misses = sess.stats.cache_misses
    for src in rng.integers(0, g_nv, n_sources):
        _, st = sess.query(SSSP(), {"source": int(src)}, warm=False)
        lat.append(st.wall_time)
    assert sess.stats.cache_misses == misses, \
        "a source sweep must not recompile"
    med, p95, p99 = _quantiles(lat)
    table(f"SSSP source sweep ({n_sources} sources, one compiled runner)",
          ["p50 ms", "p95 ms", "p99 ms", "queries/s"],
          [[f"{med*1e3:.0f}", f"{p95*1e3:.0f}", f"{p99*1e3:.0f}",
            f"{1.0/med:.1f}"]])
    recs["sweep_median_ms"] = med * 1e3
    recs["sweep_p95_ms"] = p95 * 1e3
    recs["sweep_p99_ms"] = p99 * 1e3
    return recs


def bench_update_query(sess, n_cycles):
    """update -> flush -> warm-auto query cycles: steady-state freshness
    latency (patch + upload + warm recompute; recompiles only when the
    padded shapes grow)."""
    sess.query(SSSP(), {"source": 0})
    rng = np.random.default_rng(1)
    nv = sess.pg.n_vertices
    t_cycle, recompiles = [], 0
    for _ in range(n_cycles):
        s = rng.integers(0, nv, 64)
        d = rng.integers(0, nv, 64)
        keep = s != d
        w = rng.uniform(5, 10, int(keep.sum())).astype(np.float32)
        t0 = time.perf_counter()
        sess.update(adds=(s[keep], d[keep], w))
        sess.flush()
        _, st = sess.query(SSSP(), {"source": 0})     # warm="auto"
        t_cycle.append(time.perf_counter() - t0)
        recompiles += st.compile_time > 0.0
    med, p95, p99 = _quantiles(t_cycle)
    table(f"update+flush+query cycles ({n_cycles} x 64 edges)",
          ["p50 ms", "p95 ms", "p99 ms", "recompiles", "warm queries"],
          [[f"{med*1e3:.0f}", f"{p95*1e3:.0f}", f"{p99*1e3:.0f}",
            recompiles, sess.stats.warm_queries]])
    return {"update_cycle_median_ms": med * 1e3,
            "update_cycle_p95_ms": p95 * 1e3,
            "update_cycle_p99_ms": p99 * 1e3,
            "update_cycle_recompiles": int(recompiles)}


def bench_grow(n0, n_parts, n_cycles, per_cycle, smoke, eb="coo"):
    """Growing-graph serving: each cycle attaches ``per_cycle`` brand-new
    vertices (plus edges onto random existing ones) and immediately queries
    SSSP — the continuous-update regime DRONE targets, where skewed degree
    growth makes shape churn the common case. Run twice: bucketed shapes
    (session default) vs exact padding (HEAD behavior before bucketing)."""
    policies = [("bucketed", ShapePolicy()), ("exact", ShapePolicy.exact())]
    rows, recs = [], {}
    for name, policy in policies:
        g = powerlaw_graph(n0, avg_degree=8, seed=11,
                           weighted=True).as_undirected()
        sess = GraphSession.from_graph(g, n_parts, "cdbh",
                                       shape_policy=policy,
                                       cfg=EngineConfig(edge_backend=eb))
        sess.query(SSSP(), {"source": 0})            # warm the cache
        rng = np.random.default_rng(2)
        lat, tail = [], []
        for c in range(n_cycles):
            nv = sess.pg.n_vertices
            new = np.arange(nv, nv + per_cycle, dtype=np.int64)
            anchors = rng.integers(0, nv, per_cycle).astype(np.int64)
            w = rng.uniform(1, 5, per_cycle).astype(np.float32)
            sess.update(adds=(np.concatenate([anchors, new]),
                              np.concatenate([new, anchors]),
                              np.concatenate([w, w])))
            sess.flush()
            _, st = sess.query(SSSP(), {"source": 0})     # warm="auto"
            lat.append(st.wall_time)
            tail.append(int(st.compile_time > 0.0))
        recompile_cycles = sum(tail)
        p50, p95, _ = _quantiles(lat)
        steady = n_cycles - (max(i for i, r in enumerate(tail) if r) + 1) \
            if any(tail) else n_cycles
        rows.append([name, recompile_cycles, steady,
                     f"{sess.stats.compile_time_total:.2f}",
                     f"{p50*1e3:.0f}", f"{p95*1e3:.0f}",
                     f"{sess.pg.v_max}/{sess.pg.e_max}"])
        recs[f"grow_{name}_recompile_cycles"] = int(recompile_cycles)
        recs[f"grow_{name}_steady_cycles"] = int(steady)
        recs[f"grow_{name}_p50_ms"] = p50 * 1e3
        recs[f"grow_{name}_compile_total_s"] = sess.stats.compile_time_total
    table(f"Growing-graph serving ({n_cycles} cycles x {per_cycle} new "
          f"vertices, P={n_parts})",
          ["policy", "recompile cycles", "steady tail", "compile s",
           "p50 ms", "p95 ms", "v_max/e_max"], rows)
    if smoke:
        # acceptance: buckets amortize — O(log growth) recompiles and a
        # zero-recompile steady state; exact recompiles ~every growth flush
        assert recs["grow_bucketed_recompile_cycles"] \
            < recs["grow_exact_recompile_cycles"], "buckets must win"
        assert recs["grow_bucketed_steady_cycles"] >= 2, \
            "bucketed serving must end in a 0-recompile steady state"
    return recs


def bench_edge_backends(n0, n_parts, n_cycles, per_cycle, smoke):
    """Streaming growth on every edge-compute backend: each runs the same
    insert-flush/warm-query cycle on its own (identically built) session.
    The Pallas backends must hold the serving contract — a zero-recompile
    steady state once the bucketed layout capacities settle — and return
    bit-identical SSSP distances (min_plus is exact on every backend)."""
    rows, recs = [], {}
    finals = {}
    for eb in EDGE_BACKENDS:
        g = powerlaw_graph(n0, avg_degree=8, seed=13,
                           weighted=True).as_undirected()
        sess = GraphSession.from_graph(g, n_parts, "cdbh",
                                       cfg=EngineConfig(edge_backend=eb))
        _, st0 = sess.query(SSSP(), {"source": 0})
        rng = np.random.default_rng(3)
        lat, tail = [], []
        for _ in range(n_cycles):
            nv = sess.pg.n_vertices
            new = np.arange(nv, nv + per_cycle, dtype=np.int64)
            anchors = rng.integers(0, nv, per_cycle).astype(np.int64)
            w = rng.uniform(1, 5, per_cycle).astype(np.float32)
            sess.update(adds=(np.concatenate([anchors, new]),
                              np.concatenate([new, anchors]),
                              np.concatenate([w, w])))
            sess.flush()
            res, st = sess.query(SSSP(), {"source": 0})   # warm="auto"
            lat.append(st.wall_time)
            tail.append(int(st.compile_time > 0.0))
        finals[eb] = sess.pg.collect(np.asarray(res), fill=np.inf)
        recompile_cycles = sum(tail)
        steady = n_cycles - (max(i for i, r in enumerate(tail) if r) + 1) \
            if any(tail) else n_cycles
        p50, p95, _ = _quantiles(lat)
        ss = sess.stats
        dens = (f"{ss.tile_density_min:.3f}/{ss.tile_density_mean:.3f}/"
                f"{ss.tile_density_max:.3f}"
                if eb in ("pallas_tiles", "auto") else "-")
        rows.append([eb, recompile_cycles, steady, f"{p50*1e3:.0f}",
                     f"{p95*1e3:.0f}", f"{st.backend_flops/1e6:.1f}",
                     dens])
        recs[f"eb_{eb}_recompile_cycles"] = int(recompile_cycles)
        recs[f"eb_{eb}_steady_cycles"] = int(steady)
        recs[f"eb_{eb}_p50_ms"] = p50 * 1e3
        recs[f"eb_{eb}_flops_per_query"] = int(st.backend_flops)
        if eb in ("pallas_tiles", "auto"):
            recs[f"eb_{eb}_tile_density_min"] = float(ss.tile_density_min)
            recs[f"eb_{eb}_tile_density_mean"] = float(ss.tile_density_mean)
            recs[f"eb_{eb}_tile_density_max"] = float(ss.tile_density_max)
        if eb == "auto":
            recs["eb_auto_assignment"] = list(st.partition_edge_backends)
    table(f"Edge-compute backends under streaming growth ({n_cycles} "
          f"cycles x {per_cycle} new vertices, P={n_parts})",
          ["backend", "recompile cycles", "steady tail", "p50 ms",
           "p95 ms", "Mflops/query", "tile density min/mean/max"], rows)
    for eb in EDGE_BACKENDS[1:]:
        np.testing.assert_array_equal(
            finals["coo"], finals[eb],
            err_msg=f"{eb} diverged from the COO reference")
    if smoke:
        for eb in EDGE_BACKENDS:
            assert recs[f"eb_{eb}_steady_cycles"] >= 2, \
                (f"{eb}: streaming growth must reach a zero-recompile "
                 f"steady state (got {recs[f'eb_{eb}_steady_cycles']})")
    print("edge-backend parity: SSSP bit-identical across "
          f"{', '.join(EDGE_BACKENDS)}")
    return recs


def bench_multi_tenant(n_tenants, n0, n_parts, n_rounds, q_per_round, smoke):
    """Closed-loop multi-tenant traffic (docs/SERVING.md): N same-size
    power-law graphs in one ``SessionPool`` (one shared runner cache, one
    tiered result cache), each round submitting a mixed 60/20/20
    SSSP/CC/PageRank stream per tenant through the ``MicroBatcher`` and
    draining it, with interleaved insert flushes and one deleting flush at
    half-time. Under ``--smoke`` this is the serving acceptance gate:

      - every sampled batched answer is checked against a direct
        ``query(warm=False, use_result_cache=False)`` launch — bit-identical
        for SSSP/CC, allclose for PageRank;
      - compilations must NOT scale with tenants: the shared cache compiles
        one runner per (program, batch bucket), whoever arrives first, and
        every later tenant hits it;
      - a repeated query is served from the result cache with zero device
        launches; the deleting flush makes it miss again."""
    graphs = [powerlaw_graph(n0, avg_degree=8, seed=20 + t,
                             weighted=True).as_undirected()
              for t in range(n_tenants)]
    rc = ResultCache(store=DictStore())
    pool = SessionPool(result_cache=rc, max_runners=64)
    for t, g in enumerate(graphs):
        pool.open(f"t{t}", g, n_parts=n_parts)
    bat = MicroBatcher(pool, BatchPolicy(max_batch=4, max_delay=0.005))
    rng = np.random.default_rng(5)
    lat, queue = [], []
    mismatches = 0
    buckets = set()                 # every (shape, layout) bucket observed
    for r in range(n_rounds):
        buckets |= {pool.session(f"t{t}").shape_key
                    for t in range(n_tenants)}
        futs = []
        for t in range(n_tenants):
            sess = pool.session(f"t{t}")
            nv = sess.pg.n_vertices
            for _ in range(q_per_round):
                u = rng.random()
                if u < 0.6:
                    prog, params = SSSP(), {"source": int(rng.integers(nv))}
                elif u < 0.8:
                    prog, params = ConnectedComponents(), None
                else:
                    prog, params = PageRank(tol=1e-7), {"n_vertices": nv}
                futs.append((f"t{t}", prog, params,
                             bat.submit(prog, params, tenant=f"t{t}",
                                        warm=False)))
        bat.flush()
        # drain + verify a sample against direct unbatched launches
        sample = rng.choice(len(futs), size=min(4, len(futs)),
                            replace=False)
        for i, (tname, prog, params, f) in enumerate(futs):
            res, st = f.result(timeout=120)
            lat.append(st.queue_time + st.wall_time)
            queue.append(st.queue_time)
            if i in sample:
                ref, _ = pool.session(tname).query(
                    prog, params, warm=False, use_result_cache=False)
                if isinstance(prog, PageRank):
                    ok = np.allclose(res, ref, atol=1e-6)
                else:
                    ok = np.array_equal(res, ref, equal_nan=True)
                mismatches += not ok
        # interleaved mutations: each round one tenant takes an insert
        # flush; at half-time tenant 0 takes a DELETING flush (the result-
        # cache invalidation path)
        t = r % n_tenants
        sess = pool.session(f"t{t}")
        nv = sess.pg.n_vertices
        s = rng.integers(0, nv, 32)
        d = rng.integers(0, nv, 32)
        keep = s != d
        w = rng.uniform(5, 10, int(keep.sum())).astype(np.float32)
        sess.update(adds=(s[keep], d[keep], w))
        sess.flush()
        if r == n_rounds // 2:
            s0 = pool.session("t0")
            s0.update(deletes=(graphs[0].src[:4], graphs[0].dst[:4]))
            s0.flush()

    # result-cache contract: repeat query = zero launches, delete = miss
    s0 = pool.session("t0")
    _, st_a = s0.query(SSSP(), {"source": 0}, warm=False)
    launches = s0.stats.device_launches
    _, st_b = s0.query(SSSP(), {"source": 0}, warm=False)
    rc_zero_launch = (st_b.result_cache_tier == "l1"
                      and s0.stats.device_launches == launches)
    s0.update(deletes=(graphs[0].src[4:8], graphs[0].dst[4:8]))
    s0.flush()
    _, st_c = s0.query(SSSP(), {"source": 0}, warm=False)
    rc_invalidated = st_c.result_cache_tier == "miss"

    p50, p95, p99 = _quantiles(lat)
    q50, q95, _ = _quantiles(queue)
    shared = sum(len(e.owners) > 1
                 for e in pool.runner_cache.entries.values())
    ps = pool.stats()
    table(f"Multi-tenant closed loop ({n_tenants} tenants x {n_rounds} "
          f"rounds x {q_per_round} queries, P={n_parts})",
          ["p50 ms", "p95 ms", "p99 ms", "queue p50 ms", "compiles",
           "shared runners", "batches", "fast-path hits"],
          [[f"{p50*1e3:.0f}", f"{p95*1e3:.0f}", f"{p99*1e3:.0f}",
            f"{q50*1e3:.2f}", pool.runner_cache.misses, shared,
            bat.stats.launched_batches, bat.stats.fast_path_hits]])
    recs = {"mt_tenants": n_tenants, "mt_p50_ms": p50 * 1e3,
            "mt_p95_ms": p95 * 1e3, "mt_p99_ms": p99 * 1e3,
            "mt_queue_p50_ms": q50 * 1e3, "mt_queue_p95_ms": q95 * 1e3,
            "mt_compiles": pool.runner_cache.misses,
            "mt_shared_runners": int(shared),
            "mt_batches": bat.stats.launched_batches,
            "mt_batched_requests": bat.stats.batched_requests,
            "mt_fast_path_hits": bat.stats.fast_path_hits,
            "mt_result_l1_hits": rc.stats.l1_hits,
            "mt_result_l2_hits": rc.stats.l2_hits,
            "mt_mismatches": int(mismatches)}
    if smoke:
        assert mismatches == 0, \
            f"{mismatches} batched answers diverged from direct launches"
        # 3 programs x batch buckets {1,2,4} per shape bucket bounds the
        # key space; the tenant count itself must never appear in the
        # compile count — same-bucket tenants share every runner
        bound = 9 * len(buckets)
        assert pool.runner_cache.misses <= bound, \
            (f"compiles scaled with tenants: {pool.runner_cache.misses} "
             f"> {bound} ({len(buckets)} shape buckets)")
        assert shared >= 1, "no executable was shared across tenants"
        assert rc_zero_launch, \
            "repeat query was not served from the result cache"
        assert rc_invalidated, \
            "deleting flush did not invalidate the result cache"
        print("multi-tenant smoke: batched == unbatched on every sample; "
              f"{pool.runner_cache.misses} compiles for "
              f"{len(lat)} queries across {n_tenants} tenants; "
              "result cache serves repeats and honors deleting flushes")
    pool.close_all()
    recs["mt_sessions_closed"] = ps["sessions_closed"]
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14,
                    help="Kronecker scale (2^scale vertices)")
    ap.add_argument("--parts", type=int, default=16)
    ap.add_argument("--repeat", type=int, default=10)
    ap.add_argument("--sources", type=int, default=20)
    ap.add_argument("--cycles", type=int, default=10)
    ap.add_argument("--grow", action="store_true",
                    help="add the growing-graph bucketed-vs-exact section")
    ap.add_argument("--grow-n0", type=int, default=20_000,
                    help="initial vertices for the --grow section")
    ap.add_argument("--grow-cycles", type=int, default=16)
    ap.add_argument("--grow-per-cycle", type=int, default=400,
                    help="new vertices attached per --grow cycle")
    ap.add_argument("--edge-backend", default="coo",
                    choices=EDGE_BACKENDS + ("all",),
                    help="edge-compute backend for every section, or 'all' "
                         "for the dedicated comparison section")
    ap.add_argument("--multi-tenant", action="store_true",
                    help="run ONLY the multi-tenant closed-loop section: "
                         "SessionPool + MicroBatcher + tiered result cache "
                         "under mixed per-tenant traffic")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--queries-per-round", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: exercise every path, skip scale")
    args = ap.parse_args()
    if args.smoke:
        args.scale, args.parts = 10, 8
        args.repeat, args.sources, args.cycles = 3, 5, 3
        args.grow_n0, args.grow_cycles, args.grow_per_cycle = 3_000, 8, 120

    if args.multi_tenant:
        n_tenants, n0, parts, rounds, qpr = (
            (3, 1_200, 4, 4, 5) if args.smoke
            else (args.tenants, 8_000, args.parts, args.rounds,
                  args.queries_per_round))
        rec = {"smoke": args.smoke}
        rec.update(bench_multi_tenant(n_tenants, n0, parts, rounds, qpr,
                                      args.smoke))
        save("serving_queries_multi_tenant", rec)
        return

    session_eb = "coo" if args.edge_backend == "all" else args.edge_backend
    g = kronecker_graph(args.scale, seed=7)
    sess = GraphSession.from_graph(g, args.parts, "cdbh",
                                   cfg=EngineConfig(edge_backend=session_eb))
    print(f"graph: {g.n_vertices} vertices, {g.n_edges} edges, "
          f"P={args.parts}, edge_backend={args.edge_backend}")

    rec = {"n_vertices": g.n_vertices, "n_edges": g.n_edges,
           "n_parts": args.parts, "smoke": args.smoke,
           "edge_backend": args.edge_backend}
    rec.update(bench_query_latency(sess, args.repeat, args.sources))
    rec.update(bench_update_query(sess, args.cycles))
    if args.grow:
        rec.update(bench_grow(args.grow_n0, args.parts, args.grow_cycles,
                              args.grow_per_cycle, args.smoke,
                              eb=session_eb))
    if args.edge_backend == "all":
        # deliberately small: the interpret-mode tile kernel (CPU) pays
        # ~100x over the compiled TPU path, and a big power-law graph is
        # exactly the low-density regime the density column warns tiles
        # away from anyway — this section is a contract check, not a race
        eb_n0, eb_parts, eb_cycles, eb_per = (1_200, 4, 6, 60) if args.smoke \
            else (2_000, 8, 8, 100)
        rec.update(bench_edge_backends(eb_n0, eb_parts, eb_cycles, eb_per,
                                       args.smoke))
    rec["compile_time_total_s"] = sess.stats.compile_time_total
    rec["cache_misses"] = sess.stats.cache_misses
    rec["cache_hits"] = sess.stats.cache_hits
    print(f"\nsession: {sess.stats.queries} queries served by "
          f"{sess.stats.cache_misses} compilations "
          f"({sess.stats.compile_time_total:.1f}s total compile)")
    save("serving_queries", rec)


if __name__ == "__main__":
    main()
