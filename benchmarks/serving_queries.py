"""Serving-path benchmark: cached vs cold query latency on one
``GraphSession`` over a Graph500 Kronecker graph.

Measures what the compiled-runner cache buys for continuous query traffic
(ROADMAP north star): the first (cold) query pays trace+compile once
(``ExecutionStats.compile_time``); every further query — same program,
different parameters, other algorithms already seen — runs at steady-state
latency with ``compile_time == 0``. Also times the update path: a
shape-preserving ``update+flush`` keeps the cache warm, so the post-update
query is patch + execute, no recompile.

    PYTHONPATH=src python -m benchmarks.serving_queries [--scale 14]
    PYTHONPATH=src python -m benchmarks.serving_queries --smoke   # CI
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save, table
from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.graphgen import kronecker_graph
from repro.session import GraphSession


def _quantiles(xs):
    xs = np.asarray(xs)
    return (float(np.median(xs)), float(np.percentile(xs, 95)))


def bench_query_latency(sess, n_repeat, n_sources):
    """Cold-vs-cached latency per algorithm, plus source sweep on the one
    cached SSSP runner (the multi-tenant serving pattern)."""
    g_nv = sess.pg.n_vertices
    algos = [("sssp", SSSP(), {"source": 0}),
             ("cc", ConnectedComponents(), None),
             ("pagerank", PageRank(tol=1e-7), {"n_vertices": g_nv})]
    rows, recs = [], {}
    for name, prog, params in algos:
        _, st_cold = sess.query(prog, params, warm=False)
        assert st_cold.compile_time > 0.0, "first query must compile"
        hot = []
        for _ in range(n_repeat):
            _, st = sess.query(prog, params, warm=False)
            assert st.compile_time == 0.0, "repeat query must hit the cache"
            hot.append(st.wall_time)
        med, p95 = _quantiles(hot)
        rows.append([name, f"{st_cold.compile_time:.2f}",
                     f"{st_cold.wall_time*1e3:.0f}", f"{med*1e3:.0f}",
                     f"{p95*1e3:.0f}",
                     f"{st_cold.total_time / med:.1f}x"])
        recs[f"{name}_compile_s"] = st_cold.compile_time
        recs[f"{name}_cold_ms"] = st_cold.total_time * 1e3
        recs[f"{name}_hot_median_ms"] = med * 1e3
        recs[f"{name}_hot_p95_ms"] = p95 * 1e3
    table(f"Cold vs cached query latency ({n_repeat} repeats)",
          ["algo", "compile s", "first wall ms", "hot med ms", "hot p95 ms",
           "cold/hot"], rows)

    # parameter sweep: every source reuses the one compiled SSSP runner
    rng = np.random.default_rng(0)
    lat = []
    misses = sess.stats.cache_misses
    for src in rng.integers(0, g_nv, n_sources):
        _, st = sess.query(SSSP(), {"source": int(src)}, warm=False)
        lat.append(st.wall_time)
    assert sess.stats.cache_misses == misses, \
        "a source sweep must not recompile"
    med, p95 = _quantiles(lat)
    table(f"SSSP source sweep ({n_sources} sources, one compiled runner)",
          ["med ms", "p95 ms", "queries/s"],
          [[f"{med*1e3:.0f}", f"{p95*1e3:.0f}", f"{1.0/med:.1f}"]])
    recs["sweep_median_ms"] = med * 1e3
    recs["sweep_p95_ms"] = p95 * 1e3
    return recs


def bench_update_query(sess, n_cycles):
    """update -> flush -> warm-auto query cycles: steady-state freshness
    latency (patch + upload + warm recompute; recompiles only when the
    padded shapes grow)."""
    sess.query(SSSP(), {"source": 0})
    rng = np.random.default_rng(1)
    nv = sess.pg.n_vertices
    t_cycle, recompiles = [], 0
    for _ in range(n_cycles):
        s = rng.integers(0, nv, 64)
        d = rng.integers(0, nv, 64)
        keep = s != d
        w = rng.uniform(5, 10, int(keep.sum())).astype(np.float32)
        t0 = time.perf_counter()
        sess.update(adds=(s[keep], d[keep], w))
        sess.flush()
        _, st = sess.query(SSSP(), {"source": 0})     # warm="auto"
        t_cycle.append(time.perf_counter() - t0)
        recompiles += st.compile_time > 0.0
    med, p95 = _quantiles(t_cycle)
    table(f"update+flush+query cycles ({n_cycles} x 64 edges)",
          ["med ms", "p95 ms", "recompiles", "warm queries"],
          [[f"{med*1e3:.0f}", f"{p95*1e3:.0f}", recompiles,
            sess.stats.warm_queries]])
    return {"update_cycle_median_ms": med * 1e3,
            "update_cycle_p95_ms": p95 * 1e3,
            "update_cycle_recompiles": int(recompiles)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14,
                    help="Kronecker scale (2^scale vertices)")
    ap.add_argument("--parts", type=int, default=16)
    ap.add_argument("--repeat", type=int, default=10)
    ap.add_argument("--sources", type=int, default=20)
    ap.add_argument("--cycles", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: exercise every path, skip scale")
    args = ap.parse_args()
    if args.smoke:
        args.scale, args.parts = 10, 8
        args.repeat, args.sources, args.cycles = 3, 5, 3

    g = kronecker_graph(args.scale, seed=7)
    sess = GraphSession.from_graph(g, args.parts, "cdbh")
    print(f"graph: {g.n_vertices} vertices, {g.n_edges} edges, "
          f"P={args.parts}")

    rec = {"n_vertices": g.n_vertices, "n_edges": g.n_edges,
           "n_parts": args.parts, "smoke": args.smoke}
    rec.update(bench_query_latency(sess, args.repeat, args.sources))
    rec.update(bench_update_query(sess, args.cycles))
    rec["compile_time_total_s"] = sess.stats.compile_time_total
    rec["cache_misses"] = sess.stats.cache_misses
    rec["cache_hits"] = sess.stats.cache_hits
    print(f"\nsession: {sess.stats.queries} queries served by "
          f"{sess.stats.cache_misses} compilations "
          f"({sess.stats.compile_time_total:.1f}s total compile)")
    save("serving_queries", rec)


if __name__ == "__main__":
    main()
