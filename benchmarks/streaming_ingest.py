"""Streaming subsystem benchmark: chunked-ingest throughput vs the one-shot
in-memory path, and incremental (warm-start) vs full recompute after a 1%
edge-insert batch.

    PYTHONPATH=src python -m benchmarks.streaming_ingest [--n 50000]
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from benchmarks.common import save, table
from repro.algos import SSSP
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.graphgen import powerlaw_graph
from repro.stream import (EdgeDelta, apply_delta, streaming_ingest,
                          write_edge_log)


def bench_ingest(g, n_parts, chunk_sizes):
    log_dir = tempfile.mkdtemp(prefix="drone_bench_log_")
    write_edge_log(g, log_dir, chunk_size=max(chunk_sizes))
    rows = []
    t0 = time.perf_counter()
    partition_and_build(g, n_parts, "cdbh")
    t_mem = time.perf_counter() - t0
    rows.append(["in-memory", "-", f"{g.n_edges / t_mem / 1e6:.2f}",
                 f"{g.n_edges * 20 / 2**20:.1f}", "-"])
    recs = {"in_memory_edges_per_s": g.n_edges / t_mem}
    for cs in chunk_sizes:
        d = tempfile.mkdtemp(prefix=f"drone_bench_log_{cs}_")
        write_edge_log(g, d, chunk_size=cs)
        _, _, st = streaming_ingest(d, n_parts, "cdbh")
        rows.append([f"stream c={cs}", st.n_chunks,
                     f"{st.ingest_edges_per_s / 1e6:.2f}",
                     f"{st.peak_stream_bytes / 2**20:.1f}",
                     f"{st.stream_bound_bytes / 2**20:.1f}"])
        recs[f"stream_{cs}_edges_per_s"] = st.ingest_edges_per_s
        recs[f"stream_{cs}_peak_bytes"] = st.peak_stream_bytes
    table("Chunked-ingest throughput (CDBH, "
          f"{g.n_edges} edges, P={n_parts})",
          ["path", "chunks", "Medges/s", "peak-stream MiB", "bound MiB"],
          rows)
    return recs


def bench_incremental(g, n_parts):
    log_dir = tempfile.mkdtemp(prefix="drone_bench_inc_")
    write_edge_log(g, log_dir, chunk_size=65_536)
    pg, ctx, _ = streaming_ingest(log_dir, n_parts, "cdbh")
    res, st0 = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    prev = pg.collect(res, fill=np.float32(np.inf))

    rng = np.random.default_rng(0)
    n_add = g.n_edges // 200                      # 1% counting both dirs
    s = rng.integers(0, pg.n_vertices, n_add)
    d = rng.integers(0, pg.n_vertices, n_add)
    keep = s != d
    s, d = s[keep], d[keep]
    w = rng.uniform(5, 10, s.size).astype(np.float32)
    t0 = time.perf_counter()
    dst = apply_delta(pg, ctx, EdgeDelta(
        add_src=np.concatenate([s, d]), add_dst=np.concatenate([d, s]),
        add_w=np.concatenate([w, w])))
    t_patch = time.perf_counter() - t0

    t0 = time.perf_counter()
    cold, st_c = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm, st_w = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                         init_state=prev)
    t_warm = time.perf_counter() - t0

    c = pg.collect(cold, fill=np.float32(np.inf))
    ww = pg.collect(warm, fill=np.float32(np.inf))
    fin = np.isfinite(c)
    assert np.allclose(ww[fin], c[fin], rtol=1e-5, atol=1e-4) \
        and np.isinf(ww[~fin]).all(), "warm result diverged from cold"
    assert st_w.supersteps < st_c.supersteps, \
        f"warm {st_w.supersteps} !< cold {st_c.supersteps}"
    table(f"Incremental vs full SSSP recompute (+{dst.n_added} edges, "
          f"{dst.parts_patched} partitions patched in {t_patch*1e3:.0f} ms)",
          ["run", "supersteps", "messages", "wall s"],
          [["cold (full)", st_c.supersteps, st_c.total_messages,
            f"{t_cold:.2f}"],
           ["warm (incremental)", st_w.supersteps, st_w.total_messages,
            f"{t_warm:.2f}"]])
    return {"cold_supersteps": st_c.supersteps,
            "warm_supersteps": st_w.supersteps,
            "patch_time_s": t_patch, "cold_time_s": t_cold,
            "warm_time_s": t_warm,
            "speedup_supersteps": st_c.supersteps / max(st_w.supersteps, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--parts", type=int, default=8)
    args = ap.parse_args()
    g = powerlaw_graph(args.n, avg_degree=8, seed=0,
                       weighted=True).as_undirected()
    rec = {"n_vertices": g.n_vertices, "n_edges": g.n_edges}
    rec.update(bench_ingest(g, args.parts, [16_384, 65_536, 262_144]))
    rec.update(bench_incremental(g, args.parts))
    save("streaming_ingest", rec)


if __name__ == "__main__":
    main()
