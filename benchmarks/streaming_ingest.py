"""Streaming subsystem benchmark, on the ``GraphSession`` serving API:
chunked-ingest throughput vs the one-shot in-memory path, warm-auto vs
forced-cold recompute after a 1% edge-insert batch, per-op patching vs the
session's coalescing update buffer under producer traffic, and compaction
payoff after a delete-heavy phase.

    PYTHONPATH=src python -m benchmarks.streaming_ingest [--n 50000]
    PYTHONPATH=src python -m benchmarks.streaming_ingest --smoke   # CI

``--smoke`` shrinks every stage so the whole file runs in well under a
minute on a CPU runner while still exercising the batching + compaction
code paths end to end.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from benchmarks.common import save, table
from repro.algos import SSSP
from repro.core import partition_and_build
from repro.session import GraphSession
from repro.stream import EdgeDelta, apply_delta, write_edge_log
from repro.graphgen import powerlaw_graph


def bench_ingest(g, n_parts, chunk_sizes):
    log_dir = tempfile.mkdtemp(prefix="drone_bench_log_")
    write_edge_log(g, log_dir, chunk_size=max(chunk_sizes))
    rows = []
    t0 = time.perf_counter()
    partition_and_build(g, n_parts, "cdbh")
    t_mem = time.perf_counter() - t0
    rows.append(["in-memory", "-", f"{g.n_edges / t_mem / 1e6:.2f}",
                 f"{g.n_edges * 20 / 2**20:.1f}", "-"])
    recs = {"in_memory_edges_per_s": g.n_edges / t_mem}
    for cs in chunk_sizes:
        d = tempfile.mkdtemp(prefix=f"drone_bench_log_{cs}_")
        write_edge_log(g, d, chunk_size=cs)
        st = GraphSession.from_edge_log(d, n_parts, "cdbh").ingest_stats
        rows.append([f"stream c={cs}", st.n_chunks,
                     f"{st.ingest_edges_per_s / 1e6:.2f}",
                     f"{st.peak_stream_bytes / 2**20:.1f}",
                     f"{st.stream_bound_bytes / 2**20:.1f}"])
        recs[f"stream_{cs}_edges_per_s"] = st.ingest_edges_per_s
        recs[f"stream_{cs}_peak_bytes"] = st.peak_stream_bytes
    table("Chunked-ingest throughput (CDBH, "
          f"{g.n_edges} edges, P={n_parts})",
          ["path", "chunks", "Medges/s", "peak-stream MiB", "bound MiB"],
          rows)
    return recs


def bench_incremental(g, n_parts):
    """Warm-auto vs forced-cold query on one session after a ~1% insert
    batch — the serving path (session remembers the previous converged
    result and the compiled runner)."""
    log_dir = tempfile.mkdtemp(prefix="drone_bench_inc_")
    write_edge_log(g, log_dir, chunk_size=65_536)
    # manual flush only: the whole insert batch must land as ONE patch so
    # the table's n_added/parts_patched describe it (auto-flush would split)
    sess = GraphSession.from_edge_log(log_dir, n_parts, "cdbh",
                                      max_buffer_edges=None)
    sess.query(SSSP(), {"source": 0})             # converged + compiled

    rng = np.random.default_rng(0)
    n_add = g.n_edges // 200                      # 1% counting both dirs
    s = rng.integers(0, sess.pg.n_vertices, n_add)
    d = rng.integers(0, sess.pg.n_vertices, n_add)
    keep = s != d
    s, d = s[keep], d[keep]
    w = rng.uniform(5, 10, s.size).astype(np.float32)
    t0 = time.perf_counter()
    sess.update(adds=(np.concatenate([s, d]), np.concatenate([d, s]),
                      np.concatenate([w, w])))
    dst = sess.flush()
    t_patch = time.perf_counter() - t0

    warm, st_w = sess.query(SSSP(), {"source": 0})            # warm="auto"
    cold, st_c = sess.query(SSSP(), {"source": 0}, warm=False)

    assert (np.asarray(warm) == np.asarray(cold)).all(), \
        "warm result diverged from cold"
    assert st_w.supersteps < st_c.supersteps, \
        f"warm {st_w.supersteps} !< cold {st_c.supersteps}"
    table(f"Incremental vs full SSSP recompute (+{dst.n_added} edges, "
          f"{dst.parts_patched} partitions patched in {t_patch*1e3:.0f} ms)",
          ["run", "supersteps", "messages", "compile s", "wall s"],
          [["cold (full)", st_c.supersteps, st_c.total_messages,
            f"{st_c.compile_time:.2f}", f"{st_c.wall_time:.2f}"],
           ["warm (incremental)", st_w.supersteps, st_w.total_messages,
            f"{st_w.compile_time:.2f}", f"{st_w.wall_time:.2f}"]])
    return {"cold_supersteps": st_c.supersteps,
            "warm_supersteps": st_w.supersteps,
            "patch_time_s": t_patch, "cold_time_s": st_c.wall_time,
            "warm_time_s": st_w.wall_time,
            "speedup_supersteps": st_c.supersteps / max(st_w.supersteps, 1)}


def bench_batching(g, n_parts, n_ops, flush_every):
    """Per-op apply_delta vs the session's coalescing update buffer (one
    flush per window) — the continuous-producer-traffic path
    (docs/STREAMING.md)."""
    log_dir = tempfile.mkdtemp(prefix="drone_bench_buf_")
    write_edge_log(g, log_dir, chunk_size=65_536)
    sess_seq = GraphSession.from_edge_log(log_dir, n_parts, "cdbh")
    sess_buf = GraphSession.from_edge_log(log_dir, n_parts, "cdbh",
                                          max_buffer_edges=flush_every)

    rng = np.random.default_rng(3)
    s = rng.integers(0, sess_seq.pg.n_vertices, n_ops).astype(np.int64)
    d = rng.integers(0, sess_seq.pg.n_vertices, n_ops).astype(np.int64)
    keep = s != d
    s, d = s[keep], d[keep]
    w = rng.uniform(1, 2, s.size).astype(np.float32)

    t0 = time.perf_counter()
    for i in range(s.size):
        apply_delta(sess_seq.pg, sess_seq.ctx, EdgeDelta(
            add_src=s[i:i+1], add_dst=d[i:i+1], add_w=w[i:i+1]))
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(s.size):
        sess_buf.update(adds=(s[i:i+1], d[i:i+1], w[i:i+1]))
    sess_buf.flush()
    t_buf = time.perf_counter() - t0
    assert sess_buf.pg.n_edges == sess_seq.pg.n_edges

    table(f"Delta batching ({s.size} producer add-ops, P={n_parts}, "
          f"flush_every={flush_every})",
          ["path", "patches", "ops/s", "wall s"],
          [["per-op apply_delta", s.size, f"{s.size / t_seq:.0f}",
            f"{t_seq:.2f}"],
           ["session.update", sess_buf.stats.flushes,
            f"{s.size / t_buf:.0f}", f"{t_buf:.2f}"]])
    return {"batch_ops": int(s.size),
            "batch_flushes": sess_buf.stats.flushes,
            "per_op_ops_per_s": s.size / t_seq,
            "buffered_ops_per_s": s.size / t_buf,
            "batching_speedup": t_seq / t_buf}


def bench_compaction(g, n_parts):
    """Delete-heavy phase: grow-only buffers vs compacted buffers."""
    log_dir = tempfile.mkdtemp(prefix="drone_bench_cmp_")
    write_edge_log(g, log_dir, chunk_size=65_536)
    sess = GraphSession.from_edge_log(log_dir, n_parts, "cdbh")

    rng = np.random.default_rng(4)
    sel = rng.choice(g.n_edges, size=g.n_edges // 3, replace=False)
    sess.update(deletes=(np.concatenate([g.src[sel], g.dst[sel]]),
                         np.concatenate([g.dst[sel], g.src[sel]])))
    sess.flush()
    pg = sess.pg
    v0, e0, s0 = pg.v_max, pg.e_max, pg.n_slots
    t0 = time.perf_counter()
    cs = sess.compact()
    t_cmp = time.perf_counter() - t0
    table(f"Compaction after deleting 2/3 of the edges (P={n_parts}, "
          f"{t_cmp*1e3:.0f} ms)",
          ["buffer", "grow-only", "compacted"],
          [["v_max", v0, pg.v_max], ["e_max", e0, pg.e_max],
           ["n_slots", s0, pg.n_slots],
           ["members", cs.n_evicted + int(pg.vmask.sum()),
            int(pg.vmask.sum())]])
    return {"compact_time_s": t_cmp, "compact_evicted": cs.n_evicted,
            "v_max_shrink": v0 / pg.v_max, "e_max_shrink": e0 / pg.e_max,
            "n_slots_shrink": s0 / pg.n_slots}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: exercise every path, skip scale")
    args = ap.parse_args()
    if args.smoke:
        args.n = 3_000
    g = powerlaw_graph(args.n, avg_degree=8, seed=0,
                       weighted=True).as_undirected()
    rec = {"n_vertices": g.n_vertices, "n_edges": g.n_edges,
           "smoke": args.smoke}
    chunk_sizes = [4_096, 16_384] if args.smoke else \
        [16_384, 65_536, 262_144]
    rec.update(bench_ingest(g, args.parts, chunk_sizes))
    rec.update(bench_incremental(g, args.parts))
    rec.update(bench_batching(g, args.parts,
                              n_ops=200 if args.smoke else 2_000,
                              flush_every=64 if args.smoke else 512))
    rec.update(bench_compaction(g, args.parts))
    save("streaming_ingest", rec)


if __name__ == "__main__":
    main()
