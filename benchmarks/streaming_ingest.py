"""Streaming subsystem benchmark: chunked-ingest throughput vs the one-shot
in-memory path, incremental (warm-start) vs full recompute after a 1%
edge-insert batch, per-op patching vs coalesced DeltaBuffer flushes under
producer traffic, and compaction payoff after a delete-heavy phase.

    PYTHONPATH=src python -m benchmarks.streaming_ingest [--n 50000]
    PYTHONPATH=src python -m benchmarks.streaming_ingest --smoke   # CI

``--smoke`` shrinks every stage so the whole file runs in well under a
minute on a CPU runner while still exercising the batching + compaction
code paths end to end.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from benchmarks.common import save, table
from repro.algos import SSSP
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.stream import (DeltaBuffer, EdgeDelta, apply_delta, compact,
                          streaming_ingest, write_edge_log)
from repro.graphgen import powerlaw_graph


def bench_ingest(g, n_parts, chunk_sizes):
    log_dir = tempfile.mkdtemp(prefix="drone_bench_log_")
    write_edge_log(g, log_dir, chunk_size=max(chunk_sizes))
    rows = []
    t0 = time.perf_counter()
    partition_and_build(g, n_parts, "cdbh")
    t_mem = time.perf_counter() - t0
    rows.append(["in-memory", "-", f"{g.n_edges / t_mem / 1e6:.2f}",
                 f"{g.n_edges * 20 / 2**20:.1f}", "-"])
    recs = {"in_memory_edges_per_s": g.n_edges / t_mem}
    for cs in chunk_sizes:
        d = tempfile.mkdtemp(prefix=f"drone_bench_log_{cs}_")
        write_edge_log(g, d, chunk_size=cs)
        _, _, st = streaming_ingest(d, n_parts, "cdbh")
        rows.append([f"stream c={cs}", st.n_chunks,
                     f"{st.ingest_edges_per_s / 1e6:.2f}",
                     f"{st.peak_stream_bytes / 2**20:.1f}",
                     f"{st.stream_bound_bytes / 2**20:.1f}"])
        recs[f"stream_{cs}_edges_per_s"] = st.ingest_edges_per_s
        recs[f"stream_{cs}_peak_bytes"] = st.peak_stream_bytes
    table("Chunked-ingest throughput (CDBH, "
          f"{g.n_edges} edges, P={n_parts})",
          ["path", "chunks", "Medges/s", "peak-stream MiB", "bound MiB"],
          rows)
    return recs


def bench_incremental(g, n_parts):
    log_dir = tempfile.mkdtemp(prefix="drone_bench_inc_")
    write_edge_log(g, log_dir, chunk_size=65_536)
    pg, ctx, _ = streaming_ingest(log_dir, n_parts, "cdbh")
    res, st0 = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    prev = pg.collect(res, fill=np.float32(np.inf))

    rng = np.random.default_rng(0)
    n_add = g.n_edges // 200                      # 1% counting both dirs
    s = rng.integers(0, pg.n_vertices, n_add)
    d = rng.integers(0, pg.n_vertices, n_add)
    keep = s != d
    s, d = s[keep], d[keep]
    w = rng.uniform(5, 10, s.size).astype(np.float32)
    t0 = time.perf_counter()
    dst = apply_delta(pg, ctx, EdgeDelta(
        add_src=np.concatenate([s, d]), add_dst=np.concatenate([d, s]),
        add_w=np.concatenate([w, w])))
    t_patch = time.perf_counter() - t0

    t0 = time.perf_counter()
    cold, st_c = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm, st_w = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                         init_state=prev)
    t_warm = time.perf_counter() - t0

    c = pg.collect(cold, fill=np.float32(np.inf))
    ww = pg.collect(warm, fill=np.float32(np.inf))
    fin = np.isfinite(c)
    assert np.allclose(ww[fin], c[fin], rtol=1e-5, atol=1e-4) \
        and np.isinf(ww[~fin]).all(), "warm result diverged from cold"
    assert st_w.supersteps < st_c.supersteps, \
        f"warm {st_w.supersteps} !< cold {st_c.supersteps}"
    table(f"Incremental vs full SSSP recompute (+{dst.n_added} edges, "
          f"{dst.parts_patched} partitions patched in {t_patch*1e3:.0f} ms)",
          ["run", "supersteps", "messages", "wall s"],
          [["cold (full)", st_c.supersteps, st_c.total_messages,
            f"{t_cold:.2f}"],
           ["warm (incremental)", st_w.supersteps, st_w.total_messages,
            f"{t_warm:.2f}"]])
    return {"cold_supersteps": st_c.supersteps,
            "warm_supersteps": st_w.supersteps,
            "patch_time_s": t_patch, "cold_time_s": t_cold,
            "warm_time_s": t_warm,
            "speedup_supersteps": st_c.supersteps / max(st_w.supersteps, 1)}


def bench_batching(g, n_parts, n_ops, flush_every):
    """Per-op apply_delta vs one coalesced DeltaBuffer flush per window —
    the continuous-producer-traffic path (docs/STREAMING.md)."""
    log_dir = tempfile.mkdtemp(prefix="drone_bench_buf_")
    write_edge_log(g, log_dir, chunk_size=65_536)
    pg_seq, ctx_seq, _ = streaming_ingest(log_dir, n_parts, "cdbh")
    pg_buf, ctx_buf, _ = streaming_ingest(log_dir, n_parts, "cdbh")

    rng = np.random.default_rng(3)
    s = rng.integers(0, pg_seq.n_vertices, n_ops).astype(np.int64)
    d = rng.integers(0, pg_seq.n_vertices, n_ops).astype(np.int64)
    keep = s != d
    s, d = s[keep], d[keep]
    w = rng.uniform(1, 2, s.size).astype(np.float32)

    t0 = time.perf_counter()
    for i in range(s.size):
        apply_delta(pg_seq, ctx_seq, EdgeDelta(
            add_src=s[i:i+1], add_dst=d[i:i+1], add_w=w[i:i+1]))
    t_seq = time.perf_counter() - t0

    buf = DeltaBuffer(pg_buf, ctx_buf, max_edges=flush_every)
    t0 = time.perf_counter()
    for i in range(s.size):
        buf.add(int(s[i]), int(d[i]), float(w[i]))
    buf.flush()
    t_buf = time.perf_counter() - t0
    assert pg_buf.n_edges == pg_seq.n_edges

    table(f"Delta batching ({s.size} producer add-ops, P={n_parts}, "
          f"flush_every={flush_every})",
          ["path", "patches", "ops/s", "wall s"],
          [["per-op apply_delta", s.size, f"{s.size / t_seq:.0f}",
            f"{t_seq:.2f}"],
           ["DeltaBuffer", buf.stats.n_flushes,
            f"{s.size / t_buf:.0f}", f"{t_buf:.2f}"]])
    return {"batch_ops": int(s.size), "batch_flushes": buf.stats.n_flushes,
            "per_op_ops_per_s": s.size / t_seq,
            "buffered_ops_per_s": s.size / t_buf,
            "batching_speedup": t_seq / t_buf}


def bench_compaction(g, n_parts):
    """Delete-heavy phase: grow-only buffers vs compacted buffers."""
    log_dir = tempfile.mkdtemp(prefix="drone_bench_cmp_")
    write_edge_log(g, log_dir, chunk_size=65_536)
    pg, ctx, _ = streaming_ingest(log_dir, n_parts, "cdbh")

    rng = np.random.default_rng(4)
    sel = rng.choice(g.n_edges, size=g.n_edges // 3, replace=False)
    apply_delta(pg, ctx, EdgeDelta(
        del_src=np.concatenate([g.src[sel], g.dst[sel]]),
        del_dst=np.concatenate([g.dst[sel], g.src[sel]])))
    v0, e0, s0 = pg.v_max, pg.e_max, pg.n_slots
    t0 = time.perf_counter()
    cs = compact(pg, ctx)
    t_cmp = time.perf_counter() - t0
    table(f"Compaction after deleting 2/3 of the edges (P={n_parts}, "
          f"{t_cmp*1e3:.0f} ms)",
          ["buffer", "grow-only", "compacted"],
          [["v_max", v0, pg.v_max], ["e_max", e0, pg.e_max],
           ["n_slots", s0, pg.n_slots],
           ["members", cs.n_evicted + int(pg.vmask.sum()),
            int(pg.vmask.sum())]])
    return {"compact_time_s": t_cmp, "compact_evicted": cs.n_evicted,
            "v_max_shrink": v0 / pg.v_max, "e_max_shrink": e0 / pg.e_max,
            "n_slots_shrink": s0 / pg.n_slots}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: exercise every path, skip scale")
    args = ap.parse_args()
    if args.smoke:
        args.n = 3_000
    g = powerlaw_graph(args.n, avg_degree=8, seed=0,
                       weighted=True).as_undirected()
    rec = {"n_vertices": g.n_vertices, "n_edges": g.n_edges,
           "smoke": args.smoke}
    chunk_sizes = [4_096, 16_384] if args.smoke else \
        [16_384, 65_536, 262_144]
    rec.update(bench_ingest(g, args.parts, chunk_sizes))
    rec.update(bench_incremental(g, args.parts))
    rec.update(bench_batching(g, args.parts,
                              n_ops=200 if args.smoke else 2_000,
                              flush_every=64 if args.smoke else 512))
    rec.update(bench_compaction(g, args.parts))
    save("streaming_ingest", rec)


if __name__ == "__main__":
    main()
