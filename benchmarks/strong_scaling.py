"""Paper Fig. 5 — cross-model strong scaling of CC / SSSP / PR / GSim.

Systems proxied on the shared engine (identical data structures, so
differences are attributable to the *model*, the paper's comparison axis):
  DRONE-VC  = subgraph-centric + CDBH vertex-cut   (the paper's system)
  DRONE-EC  = subgraph-centric + RH edge-cut       (Giraph++-style)
  VC-model  = vertex-centric (1-hop supersteps) + RH edge-cut (Pregel/Giraph)
"""
from __future__ import annotations

import numpy as np

from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.algos.gsim import make_gsim
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.graphgen import grid_graph, powerlaw_graph

from benchmarks.common import save, table

SYSTEMS = {
    "DRONE-VC": dict(partitioner="cdbh", mode="sc"),
    "DRONE-EC": dict(partitioner="rh-ec", mode="sc"),
    "VC-model": dict(partitioner="rh-ec", mode="vc"),
}


def _run_algo(algo, g, n_parts, sysname, labels=None):
    s = SYSTEMS[sysname]
    pg = partition_and_build(g, n_parts, s["partitioner"])
    cfg = EngineConfig(mode=s["mode"], max_supersteps=20000)
    if algo == "cc":
        return run_sim(ConnectedComponents(), pg, None, cfg)[1]
    if algo == "sssp":
        return run_sim(SSSP(), pg, {"source": 0}, cfg)[1]
    if algo == "pagerank":
        return run_sim(PageRank(tol=1e-7), pg,
                       {"n_vertices": g.n_vertices}, cfg)[1]
    pg.set_vertex_labels(labels)
    prog, params = make_gsim(np.array([[0, 1, 0], [0, 0, 1], [0, 0, 0]],
                                      np.int32),
                             np.array([0, 1, 2], np.int32))
    return run_sim(prog, pg, params, cfg)[1]


def run(scale: str = "small"):
    n = 20_000 if scale == "small" else 200_000
    workers = [4, 8, 16] if scale == "small" else [4, 8, 16, 24]
    g_pl = powerlaw_graph(n, alpha=2.2, avg_degree=12, seed=3)
    g_cc = g_pl.as_undirected()
    g_road = grid_graph(int(np.sqrt(n)), weighted=True, seed=3)
    labels = np.random.default_rng(0).integers(0, 3, size=n).astype(np.int32)

    graphs = {"cc": g_cc, "sssp": g_road, "pagerank": g_pl, "gsim": g_pl}
    rows, recs = [], []
    for algo in ("cc", "sssp", "pagerank", "gsim"):
        for sysname in SYSTEMS:
            for p in workers:
                st = _run_algo(algo, graphs[algo], p, sysname,
                               labels if algo == "gsim" else None)
                rows.append([algo, sysname, p, st.supersteps,
                             st.total_messages, f"{st.wall_time:.2f}s"])
                recs.append(dict(algo=algo, system=sysname, workers=p,
                                 supersteps=st.supersteps,
                                 messages=st.total_messages,
                                 wall_time=st.wall_time))
    table("Fig 5 — strong scaling (supersteps / messages / sim time)",
          ["algo", "system", "workers", "supersteps", "messages", "time"],
          rows)
    # paper claims (model-level): SC <= VC supersteps; DRONE-VC fewer
    # messages than VC-model on CC
    by = {(r["algo"], r["system"], r["workers"]): r for r in recs}
    for p in workers:
        assert by[("cc", "DRONE-VC", p)]["supersteps"] <= \
            by[("cc", "VC-model", p)]["supersteps"]
        assert by[("cc", "DRONE-VC", p)]["messages"] < \
            by[("cc", "VC-model", p)]["messages"]
    return save("strong_scaling", {"rows": recs, "scale": scale})


if __name__ == "__main__":
    run()
