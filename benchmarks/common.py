"""Shared benchmark utilities: result IO + table printing."""
from __future__ import annotations

import json
import os
import time


def save(name: str, payload: dict, out_dir: str = "results/bench"):
    os.makedirs(out_dir, exist_ok=True)
    payload = dict(payload, benchmark=name, unix_time=time.time())
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return payload


def table(title: str, headers, rows):
    print(f"\n== {title} ==")
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)] if rows else [len(h) for h in headers]
    line = "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
