"""Paper headline — trillion-edge capability. Compile-only proof on the
production mesh: the BSP CC superstep loop at 2^40 edges across 512 chips
(sharded SBS) must lower+compile and fit per-device HBM. Reads the JSON the
graph dry-run produced (or produces it)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import save, table


def run(scale: str = "small"):
    path = "results/dryrun/graph__trillion__cc__multipod.json"
    if not os.path.exists(path):
        subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun_graph", "--scale",
             "trillion", "--algo", "cc", "--mesh", "multipod"],
            check=False, env=dict(os.environ, PYTHONPATH="src"))
    if not os.path.exists(path):
        print("trillion dry-run artifact missing (run dryrun_graph)")
        return None
    rec = json.load(open(path))
    rows = [[rec["status"], rec.get("n_parts"),
             rec.get("meta", {}).get("e_max"),
             f"{rec.get('memory', {}).get('temp_size_in_bytes', 0)/2**30:.2f}GiB",
             f"{rec.get('walk', {}).get('collective_bytes_per_device', 0)/2**20:.1f}MiB"]]
    table("Trillion-edge capability (2^40 edges, 512 chips, compile-only)",
          ["status", "subgraphs", "edges/part", "temp/dev", "coll bytes/dev"],
          rows)
    return save("trillion_dryrun", rec)


if __name__ == "__main__":
    run()
