"""Paper Fig. 9 — weak scaling on Graph500 Kronecker graphs: fixed edges per
partition, growing scale; performance in PEPS (actual processed edges per
second) per worker. CPU-sim absolute numbers are not TPU numbers — the curve
*shape* (PEPS/worker vs scale) is the reproduction target.

Also includes the trillion-edge *capability* dry-run marker: see
benchmarks/trillion_dryrun.py (compile-only, 512 devices).
"""
from __future__ import annotations

from repro.algos import ConnectedComponents, PageRank
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.graphgen import kronecker_graph

from benchmarks.common import save, table


def run(scale: str = "small"):
    scales = [12, 13, 14] if scale == "small" else [14, 15, 16, 17]
    base_parts = 4
    rows, recs = [], []
    for i, sc in enumerate(scales):
        g = kronecker_graph(sc, seed=6)
        p = base_parts * (2 ** i)              # fixed edges/partition
        pg = partition_and_build(g, p, "cdbh")
        for aname in ("cc", "pagerank"):
            if aname == "cc":
                _, st = run_sim(ConnectedComponents(), pg, None,
                                EngineConfig(mode="sc"))
            else:
                _, st = run_sim(PageRank(tol=1e-6), pg,
                                {"n_vertices": g.n_vertices},
                                EngineConfig(mode="sc", max_local_iters=100))
            peps_w = st.peps / p
            rows.append([aname, sc, p, g.n_edges, st.supersteps,
                         f"{st.peps:.3e}", f"{peps_w:.3e}"])
            recs.append(dict(algo=aname, scale=sc, workers=p,
                             edges=g.n_edges, supersteps=st.supersteps,
                             peps=st.peps, peps_per_worker=peps_w))
    table("Fig 9 — weak scaling on Kronecker graphs (PEPS/worker)",
          ["algo", "scale", "workers", "edges", "supersteps", "PEPS",
           "PEPS/worker"], rows)
    return save("weak_scaling", {"rows": recs})


if __name__ == "__main__":
    run()
