"""Paper Table 4 + Figs 6/7 — SSSP under DRONE variants on a power-law graph
(WebBase proxy) and a road-network proxy: execution time, supersteps,
messages-per-superstep traces for DRONE-VC-CDBH / DRONE-VC-RH / DRONE-EC-RH."""
from __future__ import annotations

import numpy as np

from repro.algos import SSSP
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.graphgen import grid_graph, kronecker_graph

from benchmarks.common import save, table

VARIANTS = {
    "DRONE-VC-CDBH": ("cdbh", "sc"),
    "DRONE-VC-RH": ("rh-vc", "sc"),
    "DRONE-EC-RH": ("rh-ec", "sc"),
}


def run(scale: str = "small"):
    g = kronecker_graph(14 if scale == "small" else 18, seed=4, weighted=True)
    road = grid_graph(100 if scale == "small" else 300, weighted=True, seed=4)
    rows, recs = [], {}
    for gname, graph in (("kron-powerlaw", g), ("road-grid", road)):
        for vname, (pname, mode) in VARIANTS.items():
            pg = partition_and_build(graph, 16, pname)
            cfg = EngineConfig(mode=mode, trace=True, max_supersteps=20000)
            _, st = run_sim(SSSP(), pg, {"source": 0}, cfg)
            rows.append([gname, vname, st.supersteps, st.total_messages,
                         f"{st.wall_time:.2f}s"])
            recs[f"{gname}/{vname}"] = dict(
                supersteps=st.supersteps, messages=st.total_messages,
                wall_time=st.wall_time,
                messages_per_step=st.messages_per_step[:200])
    table("Table 4 / Fig 7 — SSSP DRONE variants",
          ["graph", "variant", "supersteps", "messages", "time"], rows)
    return save("sssp_variants", recs)


if __name__ == "__main__":
    run()
